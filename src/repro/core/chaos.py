"""Seeded, deterministic chaos harness for composed fault soaks.

A :class:`ChaosPlan` expands one integer seed into a reproducible
schedule of fault events over a serving stack: silent corruption
(bit-flips in ranks / tiles / slot tables / operand mirrors, dropped or
duplicated operand scatters, host-graph corruption — the corruption
domain, `core/integrity.py`) composed with the existing domains' faults
(slot kill / stall from the session domain; a thread-domain
``FaultPlan`` can ride the session config of the same soak).  The same
seed always produces the same schedule, so a chaos failure replays
exactly — the property the ``chaos`` smoke scenario
(`benchmarks/run.py`) and the ``chaos``-marked soak test gate on.

The plan is pure data: the harness that owns the serving stack walks
``events_at(step)`` and applies each event through the public injection
surfaces (``session.inject_corruption``, ``svc.inject_session_fault``).
At most one event lands per (step, stream), so detection accounting
stays 1:1 — every injected corruption maps to exactly one scrub
detection.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import fault_domain as fd

#: Everything a plan can schedule: the corruption kinds plus the
#: session-domain slot faults.
CHAOS_KINDS = fd.CORRUPTION_KINDS + ("slot_dead", "slot_stuck")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault: at soak step ``step``, against serving slot
    ``stream``.  ``seed`` parameterizes the injection site (which
    vertex / tile / bit) deterministically."""
    step: int
    stream: int
    kind: str
    seed: int = 0

    def __post_init__(self):
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r} "
                             f"(expected one of {list(CHAOS_KINDS)})")

    def corruption(self) -> Optional[fd.CorruptionFault]:
        """The corruption-domain fault for this event, or None for a
        session-domain event."""
        if self.kind in fd.CORRUPTION_KINDS:
            return fd.CorruptionFault(kind=self.kind, seed=self.seed)
        return None

    def session_fault(self, *, stall_s: float = 0.0
                      ) -> Optional[fd.SessionFault]:
        if self.kind == "slot_dead":
            return fd.SessionFault(stream=self.stream, kind="dead")
        if self.kind == "slot_stuck":
            return fd.SessionFault(stream=self.stream, kind="stuck",
                                   stall_s=stall_s)
        return None

    def to_dict(self) -> dict:
        return {"step": int(self.step), "stream": int(self.stream),
                "kind": self.kind, "seed": int(self.seed)}


class ChaosPlan:
    """Deterministic composed-fault schedule.

    ``require`` lists kinds that must appear at least once (the smoke
    scenario requires one trigger per repair-ladder rung); ``rate`` adds
    extra seeded events on top until roughly ``rate`` of the
    (step, stream) grid carries one.  Events never share a
    (step, stream) cell.
    """

    def __init__(self, *, seed: int, steps: int, streams: int,
                 kinds: Sequence[str] = fd.CORRUPTION_KINDS,
                 require: Sequence[str] = (), rate: float = 0.0):
        if steps <= 0 or streams <= 0:
            raise ValueError("steps and streams must be positive")
        kinds = tuple(kinds)
        for k in tuple(require) + kinds:
            if k not in CHAOS_KINDS:
                raise ValueError(f"unknown chaos kind {k!r}")
        if len(require) > steps * streams:
            raise ValueError(
                f"{len(require)} required events do not fit the "
                f"{steps}x{streams} (step, stream) grid")
        self.seed = int(seed)
        self.steps = int(steps)
        self.streams = int(streams)
        rng = np.random.default_rng(self.seed)
        cells = [(s, t) for s in range(steps) for t in range(streams)]
        order = rng.permutation(len(cells))
        events: List[ChaosEvent] = []
        used = set()
        for i, kind in enumerate(require):
            s, t = cells[order[i]]
            used.add((s, t))
            events.append(ChaosEvent(step=s, stream=t, kind=kind,
                                     seed=int(rng.integers(1 << 31))))
        if rate > 0 and kinds:
            for (s, t) in cells:
                if (s, t) in used or rng.random() >= rate:
                    continue
                events.append(ChaosEvent(
                    step=s, stream=t, kind=str(rng.choice(kinds)),
                    seed=int(rng.integers(1 << 31))))
        self.events: Tuple[ChaosEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.step, e.stream)))

    def events_at(self, step: int) -> Tuple[ChaosEvent, ...]:
        return tuple(e for e in self.events if e.step == step)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    @property
    def corruption_events(self) -> Tuple[ChaosEvent, ...]:
        return tuple(e for e in self.events
                     if e.kind in fd.CORRUPTION_KINDS)

    def to_dict(self) -> dict:
        return {"seed": self.seed, "steps": self.steps,
                "streams": self.streams,
                "events": [e.to_dict() for e in self.events]}
