"""Dynamic Frontier (DF) marking — paper §4.1, and DT reachability marking.

All marking is expressed as idempotent OR-scatters / OR-SpMVs, which is what
makes the paper's helping mechanism race-free; the same property makes our
re-execution-based fault recovery exact.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.graph import GraphSnapshot, out_neighbor_or


def pack_batch(n_pad: int, deletions: np.ndarray, insertions: np.ndarray,
               *, bucket: int = 1024) -> jnp.ndarray:
    """Pack a batch update into a padded [b_pad, 2] i32 device array.
    Padded rows use the phantom vertex ``n_pad`` as source.  Snapshot-free
    variant for the streaming runtime (only the pad size is needed)."""
    b = np.concatenate([np.asarray(deletions, np.int64).reshape(-1, 2),
                        np.asarray(insertions, np.int64).reshape(-1, 2)], 0)
    b_pad = max(bucket, ((len(b) + bucket - 1) // bucket) * bucket)
    out = np.full((b_pad, 2), n_pad, dtype=np.int32)
    if len(b):
        out[:len(b)] = b
    return jnp.asarray(out)


def batch_to_device(g: GraphSnapshot, deletions: np.ndarray,
                    insertions: np.ndarray, *, bucket: int = 1024
                    ) -> jnp.ndarray:
    """Snapshot-keyed convenience wrapper around :func:`pack_batch`."""
    return pack_batch(g.n_pad, deletions, insertions, bucket=bucket)


def update_sources_indicator(g: GraphSnapshot, batch: jnp.ndarray
                             ) -> jnp.ndarray:
    """Indicator [n_pad] of source vertices appearing in the batch update."""
    ind = jnp.zeros((g.n_pad + 1,), dtype=bool)
    ind = ind.at[jnp.minimum(batch[:, 0], g.n_pad)].set(True)
    return ind[:g.n_pad] & g.vertex_valid


def initial_affected(g_prev: GraphSnapshot, g_cur: GraphSnapshot,
                     batch: jnp.ndarray) -> jnp.ndarray:
    """Paper lines 4-6 (Alg. 1): mark out-neighbors of every update source in
    both G^{t-1} and G^t.  Sources themselves are *not* marked."""
    ind_prev = update_sources_indicator(g_prev, batch)
    ind_cur = update_sources_indicator(g_cur, batch)
    aff = out_neighbor_or(g_prev, ind_prev) | out_neighbor_or(g_cur, ind_cur)
    return aff & g_cur.vertex_valid


def initial_affected_with_helping(
        g_prev: GraphSnapshot, g_cur: GraphSnapshot, batch: jnp.ndarray,
        first_pass_mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Fault-tolerant phase-1 marking with the paper's *helping* mechanism
    (Alg. 2 lines 5-16).

    ``first_pass_mask`` [b_pad] simulates which update edges the (possibly
    delayed/crashed) first owners actually processed.  The helping loop then
    re-processes every update whose checked flag ``C`` is still 0 — idempotent
    OR-marking makes duplicated work harmless.  Returns (affected, C, rounds).
    """
    n_pad = g_cur.n_pad
    real = batch[:, 0] < n_pad

    def mark(subset_mask: jnp.ndarray) -> jnp.ndarray:
        sub = jnp.where(subset_mask[:, None], batch,
                        jnp.full_like(batch, n_pad))
        return initial_affected(g_prev, g_cur, sub)

    affected = mark(first_pass_mask & real)
    C = (first_pass_mask & real) | ~real   # padded rows count as checked

    # helping rounds: any thread observing C[u]=0 re-processes that update
    rounds = 0
    # one helping round suffices functionally (survivors process everything
    # left); loop kept to mirror the paper's "while true ... all marked?"
    while bool((~C).any()):
        remaining = ~C
        affected = affected | mark(remaining)
        C = C | remaining
        rounds += 1
    return affected, C, rounds


def dt_affected(g_prev: GraphSnapshot, g_cur: GraphSnapshot,
                batch: jnp.ndarray, *, max_hops: int = 0) -> jnp.ndarray:
    """Dynamic Traversal marking (Alg. 7): everything *reachable* in G^t from
    the out-neighbors of update sources.  BFS as iterated OR-SpMV."""
    frontier = initial_affected(g_prev, g_cur, batch)
    affected = frontier
    hops = max_hops or g_cur.n_blocks * g_cur.block_size

    def cond(state):
        frontier, affected, i = state
        return jnp.logical_and(frontier.any(), i < hops)

    def body(state):
        frontier, affected, i = state
        new = out_neighbor_or(g_cur, frontier) & ~affected
        return new, affected | new, i + 1

    _, affected, _ = jax.lax.while_loop(
        cond, body, (frontier, affected, jnp.int32(0)))
    return affected


def block_any(flags: jnp.ndarray, n_blocks: int, block_size: int
              ) -> jnp.ndarray:
    """Per-block OR over a [n_pad] vertex indicator → [n_blocks] bool.
    Shared by the blocked engine's compaction and the fused Pallas driver."""
    return flags[:n_blocks * block_size].reshape(n_blocks,
                                                 block_size).any(axis=1)


def compact_block_ids(act: jnp.ndarray, n_blocks: int) -> jnp.ndarray:
    """Compacted active-block slot list: active ids first, then -1 padding.
    jit-safe (static ``size=``); the Pallas kernels prefetch this list."""
    return jnp.nonzero(act, size=n_blocks,
                       fill_value=-1)[0].astype(jnp.int32)


def expand_frontier(g: GraphSnapshot, changed: jnp.ndarray,
                    affected: jnp.ndarray, rc: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper lines 15-17 (Alg. 1) / 25-28 (Alg. 2): mark out-neighbors of
    vertices whose rank moved more than τ_f; dense OR-SpMV form (the blocked
    engine does the same per-block with edge-proportional work)."""
    hit = out_neighbor_or(g, changed)
    return affected | hit, rc | hit
