"""Fused Pallas frontier engine — the whole DF_LF sweep loop on-device.

The blocked engine (:mod:`repro.core.blocked`) drives its sweeps from Python:
every iteration pays a host↔device round-trip for the active count, the
convergence flag and the per-sweep stats, and the pull itself is a
``segment_sum`` gather with no MXU mapping.  This engine removes both costs:

  1. the pull runs through the block-sparse tile SpMV
     (:mod:`repro.kernels.block_spmv`) over *compacted active row-block
     ids* — a sweep touches only frontier blocks and each touched block is
     a dense B×B tile (sum semiring).  Two backends share the layout: the
     Pallas kernels (MXU on TPU, scalar-prefetched ids) and an XLA
     gather/einsum path that makes CPU containers fast too
     (``ops.default_backend`` picks per platform);
  2. Dynamic Frontier expansion is the same kernel in the OR semiring,
     restricted to the *candidate* row-blocks whose tiles intersect a
     changed column-block (tile-presence adjacency, maintained
     incrementally across a stream);
  3. the driver is a single ``lax.while_loop`` containing compaction,
     the sweep, the τ/RC convergence test and fault-mask application.
     Zero host syncs until convergence; stats come back as one device
     array.  Kernel launches are *frontier-proportional*: the active-count
     selects a bucket from a static doubling ladder via ``lax.switch``
     (``ops.block_spmv_active_bucketed``), so the grid scales with the
     actual frontier instead of ``n_rb``.

The driver deliberately does **not** consume a :class:`GraphSnapshot`: its
operands are the per-vertex vectors (``valid``, ``out_deg``), the per-block
degree vectors (``rb_in``/``rb_out``), the tile-presence adjacency ``bmat``
and the capacity-padded pull matrix.  All of those keep stable shapes (and
stable pytree aux) across a dynamic stream, so after one warmup trace a
stream of delta batches re-enters the compiled driver with **zero
retraces** — a snapshot's ``m`` changing per batch would otherwise retrace
on nearly every step (see :mod:`repro.core.stream`).

Within a sweep the update is block-Jacobi (all active blocks read the
sweep-start ranks) — the lock-free *scheduling* semantics of DF_LF (per-block
work pool, per-vertex RC termination, τ_f-gated expansion, crash/delay
masks) are preserved — as in the blocked engine, a delayed or crashed
thread's slots are picked up by the surviving threads (charged to simulated
time), never deferred — while the blocked engine's in-sweep Gauss–Seidel
ordering is traded for barrier-free device execution.  Both converge to the
same fixed point within the paper's τ_f error bound; the blocked engine
remains as the Gauss–Seidel oracle.

On CPU containers the Pallas kernels would run in interpret mode
(``interpret=True``, semantics-validating only) — production CPU runs use
``backend="xla"`` instead.  f64 ranks are supported off-TPU only (the MXU
has no f64 path) — see docs/ENGINES.md.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import faults as flt
from repro.core import frontier as fr
from repro.core.blocked import SweepStats
from repro.core.graph import GraphSnapshot
from repro.kernels.block_spmv import ops


def build_pull_matrix(g: GraphSnapshot, dtype=np.float64,
                      padded: bool = False) -> ops.BlockSparse:
    """Block-sparse pull matrix for a snapshot: A[v, u] = 1 iff edge u→v
    (self-loops included), padded to the snapshot's block grid so row-blocks
    coincide with the engine's vertex blocks.  ``padded=True`` preallocates
    the tile pool / slot tables on the growth ladder (streaming layout)."""
    src, dst = g.in_edges_host()
    return ops.build_block_sparse(dst, src, g.n_pad, g.n_pad,
                                  block=g.block_size, dtype=dtype,
                                  padded=padded)


def default_interpret() -> bool:
    """Pallas interpret mode on anything that is not a real TPU."""
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("n", "block_size", "mode", "expand",
                                   "active_policy", "max_iterations",
                                   "interpret", "backend", "tiered"))
def _driver(mat: ops.BlockSparse, R0, affected0, valid, out_deg,
            rb_in, rb_out, bmat, rb_res, alpha, tau, tau_f,
            part_table, alive_table, delay_table, crashed_any, *,
            n: int, block_size: int, mode: str, expand: bool,
            active_policy: str, max_iterations: int, interpret: bool,
            backend: str, tiered: bool = False):
    """The fused loop.  Returns (ranks [n_pad], stats vector [7],
    deferred row-block indicator [n_rb]).

    Every operand keeps a stable shape across a dynamic stream (the pull
    matrix is capacity-padded; the degree/adjacency vectors are per-block,
    the grid is fixed), so a stream re-enters one compiled trace.

    ``tiered=True`` (tiered storage, :mod:`repro.core.tiering`): ``mat`` is
    the device *hot-slab view* and ``rb_res`` marks which row-blocks are
    resident.  A non-resident block is never swept — seeds landing in it and
    expansion candidates touching it are recorded in the ``deferred``
    indicator instead (the whole block is re-marked, mirroring the helping
    mechanism: another drive picks the work up after admission, with **no
    mid-sweep host sync**).  The caller loops admit(deferred) → re-drive
    until the indicator is empty.  Untiered callers pass ``rb_res`` all-True
    and get an all-False indicator back.
    """
    dtype = R0.dtype
    B = block_size
    n_pad = valid.shape[0]
    n_rb = n_pad // B
    jacobi = mode == "bb"
    # counters accumulate in float: f64 (x64 on) is integer-exact to 2^53;
    # without x64 an int32 would wrap past 2^31 edges whereas f32 degrades
    # gracefully (and the returned stats vector is f32 there anyway)
    cdt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    ladder = ops.active_ladder(n_rb)

    deg = jnp.maximum(out_deg, 1).astype(dtype)
    inv_deg = jnp.where(valid, 1.0 / deg, 0).astype(dtype)
    base = ((1.0 - alpha) / n).astype(dtype)
    alpha_c = alpha.astype(dtype)
    tau_c = tau.astype(dtype)
    tau_f_c = tau_f.astype(dtype)
    n_threads = part_table.shape[1]

    R = jnp.where(valid, R0[:n_pad], 0).astype(dtype)
    affected = affected0[:n_pad] & valid
    if tiered:
        # seeds in non-resident blocks are deferred wholesale before the loop
        res_v = jnp.repeat(rb_res, B)
        deferred0 = fr.block_any(affected & ~res_v, n_rb, B)
        affected = affected & res_v
    else:
        deferred0 = jnp.zeros((n_rb,), bool)
    RC = affected

    def cond(state):
        (_, _, _, it, converged, dnf, _, _) = state
        return ~converged & ~dnf & (it < max_iterations)

    def body(state):
        R, affected, RC, it, converged, dnf, deferred, ctr = state
        act_flags = affected if active_policy == "affected" else RC
        act_rb = fr.block_any(act_flags, n_rb, B)
        n_act = act_rb.sum()
        no_work = n_act == 0

        if jacobi:
            participate = jnp.ones((n_threads,), bool)
            crash_now = crashed_any[it] & ~no_work
            asleep = jnp.asarray(False)
        else:
            participate = part_table[it]
            crash_now = jnp.asarray(False)
            asleep = ~participate.any() & ~no_work
        do = ~no_work & ~crash_now & ~asleep

        # -- compacted frontier sweep: pull over active row-blocks only,
        #    launched at the smallest ladder bucket ≥ |active| -------------
        ids = jnp.where(do, fr.compact_block_ids(act_rb, n_rb), -1)
        n_eff = jnp.where(do, n_act, 0)
        pulled = ops.block_spmv_active_bucketed(
            mat, R * inv_deg, ids, n_eff, semiring="sum",
            interpret=interpret, backend=backend, ladder=ladder)
        r_new = base + alpha_c * pulled
        act_v = jnp.repeat(act_rb, B)
        upd = affected & act_v & valid & do
        r_fin = jnp.where(upd, r_new, R)
        dr = jnp.where(upd, jnp.abs(r_fin - R), 0)
        maxdr = dr.max()
        RC1 = jnp.where(upd, dr > tau_c, RC)

        # -- DF expansion: OR semiring over candidate row-blocks ------------
        if expand:
            changed = upd & (dr > tau_f_c)
            ch_cb = fr.block_any(changed, n_rb, B)
            cand_rb = (bmat & ch_cb[None, :]).any(axis=1)
            if tiered:
                # candidate blocks not on device: defer (re-mark for the
                # next drive after admission) instead of syncing mid-sweep
                deferred = deferred | (cand_rb & ~rb_res & do)
                cand_rb = cand_rb & rb_res
            n_cand = jnp.where(do, cand_rb.sum(), 0)
            cids = jnp.where(do, fr.compact_block_ids(cand_rb, n_rb), -1)
            hitf = ops.block_spmv_active_bucketed(
                mat, changed.astype(dtype), cids, n_cand, semiring="or",
                interpret=interpret, backend=backend, ladder=ladder)
            hit = (hitf > 0) & jnp.repeat(cand_rb, B) & valid & do
            affected1 = affected | hit
            RC1 = RC1 | hit
            out_rb = jnp.where(ch_cb, rb_out, 0)
        else:
            affected1 = affected
            ch_cb = jnp.zeros((n_rb,), bool)
            out_rb = jnp.zeros((n_rb,), rb_out.dtype)

        # -- work accounting + fault-time model (paper §5.1.6) --------------
        in_rb = jnp.where(act_rb, rb_in, 0)
        e_sweep = jnp.where(do, (in_rb + out_rb).astype(cdt).sum(), 0)
        ids_c = jnp.maximum(ids, 0)
        real_slot = ids >= 0
        slot_edges = jnp.where(
            real_slot,
            rb_in[ids_c] + jnp.where(ch_cb[ids_c], rb_out[ids_c], 0),
            0).astype(jnp.float32)
        pid = jnp.nonzero(participate, size=n_threads, fill_value=0)[0]
        w = participate.sum()
        tid = pid[jnp.arange(n_rb) % jnp.maximum(w, 1)]
        th_edges = jax.ops.segment_sum(slot_edges, tid,
                                       num_segments=n_threads)
        th_blocks = jax.ops.segment_sum(real_slot.astype(jnp.float32), tid,
                                        num_segments=n_threads)
        work_ms = (th_edges * flt.T_EDGE_NS
                   + th_blocks * flt.T_BLOCK_NS) * 1e-6
        delay_row = delay_table[it]
        alive = alive_table[it]
        if jacobi:
            step_ms = jnp.max(work_ms + delay_row)
        else:
            step_ms = jnp.where(
                asleep, jnp.max(jnp.where(alive, delay_row, 0)),
                jnp.max(jnp.where(alive, work_ms, 0)))
        step_ms = jnp.where(do | asleep, step_ms, 0.0)

        # -- convergence ----------------------------------------------------
        if jacobi:
            conv_after = do & (maxdr <= tau_c)
        else:
            # RC-empty is the paper's LF criterion; the maxdr escape stops
            # a float limit cycle: when τ_f sits below the ulp floor, a
            # period-2 fixed point jitters forever above τ_f and the
            # expansion re-marks RC every sweep even though no vertex has
            # moved more than τ — abandoning that sub-τ wave inflates the
            # error by at most τ·α/(1−α), the paper's own stability bound
            conv_after = do & ((maxdr <= tau_c) | ~(RC1 & valid).any())
        converged1 = converged | no_work | conv_after
        dnf1 = dnf | crash_now

        sweeps, iters, blocks, edges, sim = ctr
        ctr1 = (sweeps + jnp.where(do | asleep, 1, 0).astype(cdt),
                iters + jnp.where(do, 1, 0).astype(cdt),
                blocks + jnp.where(do, n_act, 0).astype(cdt),
                edges + e_sweep,
                sim + step_ms.astype(jnp.float32))
        return (r_fin, affected1, RC1, it + 1, converged1, dnf1, deferred,
                ctr1)

    zero = jnp.zeros((), cdt)
    init = (R, affected, RC, jnp.int32(0), jnp.asarray(False),
            jnp.asarray(False), deferred0,
            (zero, zero, zero, zero, jnp.zeros((), jnp.float32)))
    R, _, _, _, converged, dnf, deferred, ctr = lax.while_loop(
        cond, body, init)
    sweeps, iters, blocks, edges, sim = ctr
    fdt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    stats = jnp.stack([sweeps.astype(fdt), iters.astype(fdt),
                       blocks.astype(fdt), edges.astype(fdt),
                       sim.astype(fdt), converged.astype(fdt),
                       dnf.astype(fdt)])
    return R, stats, deferred


def _stats_from_vec(sv: np.ndarray) -> SweepStats:
    return SweepStats(
        sweeps=int(sv[0]), iterations=int(sv[1]), blocks_processed=int(sv[2]),
        edges_processed=int(sv[3]), sim_time_ms=float(sv[4]),
        converged=bool(sv[5] > 0), dnf=bool(sv[6] > 0))


def run_pallas(g: GraphSnapshot, R0: jnp.ndarray, affected0: jnp.ndarray,
               *, mode: str = "lf", expand: bool = True,
               alpha: float = 0.85, tau: float = 1e-10,
               tau_f: Optional[float] = None, max_iterations: int = 500,
               faults: Optional[flt.FaultPlan] = None,
               active_policy: str = "affected",
               mat: Optional[ops.BlockSparse] = None,
               aux=None,
               interpret: Optional[bool] = None,
               backend: Optional[str] = None,
               ) -> Tuple[jnp.ndarray, SweepStats]:
    """Fused-engine entry point; signature mirrors ``blocked.run_blocked``.

    ``mat`` may be supplied (e.g. maintained incrementally across a dynamic
    stream via :class:`repro.core.incremental.IncrementalPullMatrix`);
    otherwise it is built from the snapshot.  ``aux`` may carry the cached
    per-block vectors (any object with ``bmat`` / ``rb_in`` / ``rb_out``
    attributes, e.g. ``IncrementalPullMatrix.aux``) so a stream avoids
    recomputing the tile-presence adjacency and block-degree vectors per
    call.  ``backend`` picks the tile-SpMV backend
    (:func:`repro.kernels.block_spmv.ops.default_backend` when None).  The
    convergence loop itself performs **zero** host synchronisations — the
    only transfer is the final (ranks, stats) fetch after the
    ``while_loop`` exits.
    """
    if mode not in ("lf", "bb"):
        raise ValueError(mode)
    if active_policy not in ("affected", "rc"):
        raise ValueError(active_policy)
    if tau_f is None:
        tau_f = tau / 1000.0 if expand else float("inf")
    if not expand:
        tau_f = float("inf")
    if interpret is None:
        interpret = default_interpret()
    backend = ops._resolve_backend(backend)
    plan = faults or flt.NO_FAULTS
    dtype = R0.dtype
    if mat is None:
        mat = build_pull_matrix(g, dtype=np.dtype(dtype))
    elif mat.block != g.block_size or mat.n_rows != g.n_pad:
        raise ValueError(
            f"pull matrix grid (block={mat.block}, n_rows={mat.n_rows}) "
            f"does not match snapshot (block={g.block_size}, "
            f"n_pad={g.n_pad}); rebuild with build_pull_matrix")

    if aux is not None:
        rb_in, rb_out = jnp.asarray(aux.rb_in), jnp.asarray(aux.rb_out)
        bmat = jnp.asarray(aux.bmat)
    else:
        rb_in, rb_out = g.block_in_edges(), g.block_out_edges()
        bmat = ops.block_adjacency(mat)

    part, alive, delay, crashed = plan.device_tables(max_iterations)
    f = jnp.asarray
    rb_res = jnp.ones((mat.n_rb,), bool)    # untiered: everything resident
    R, stats_vec, _ = _driver(
        mat, R0[:g.n_pad], affected0[:g.n_pad], g.vertex_valid, g.out_deg,
        rb_in, rb_out, bmat, rb_res,
        f(alpha), f(tau), f(tau_f),
        f(part), f(alive), f(delay), f(crashed),
        n=g.n, block_size=g.block_size, mode=mode, expand=expand,
        active_policy=active_policy, max_iterations=max_iterations,
        interpret=interpret, backend=backend)
    sv = np.asarray(jax.block_until_ready(stats_vec))   # the single sync
    return R[:g.n_pad], _stats_from_vec(sv)


# ---------------------------------------------------------------------------
# repro.api engine adapter (Engine protocol; discovered lazily by
# repro.api.registry so this module never imports the api package)
# ---------------------------------------------------------------------------

class PallasEngine:
    """Registry adapter for the fused frontier engine.  ``mat`` / ``aux``
    carry the incrementally maintained pull matrix + per-block operands
    (:class:`repro.core.incremental.IncrementalPullMatrix`); ``backend``
    picks the tile-SpMV backend."""

    name = "pallas"
    fault_domains = ("thread", "process", "corruption")

    def run(self, g, R0, affected0, *, mode, expand, alpha, tau, tau_f,
            max_iterations, faults, tile, active_policy,
            mat=None, aux=None, backend=None, interpret=None, shards=None):
        from repro.api.registry import reject_shard_spec
        reject_shard_spec(self.name, shards)
        del tile    # blocked-engine knob; the fused driver launches tiles
        R, stats = run_pallas(
            g, R0, affected0, mode=mode, expand=expand, alpha=alpha,
            tau=tau, tau_f=tau_f, max_iterations=max_iterations,
            faults=faults, active_policy=active_policy, mat=mat, aux=aux,
            backend=backend, interpret=interpret)
        return jax.block_until_ready(R), stats


def as_engine() -> PallasEngine:
    return PallasEngine()
