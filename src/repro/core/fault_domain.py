"""Unified fault domains: thread, shard, process, session, and
corruption faults behind one recovery abstraction (docs/FAULTS.md).

The paper's claim is that DF_LF "withstands random thread delays and
crashes"; the non-blocking PageRank line of work argues fault tolerance
must be a property of the *whole pipeline*.  This module is the one place
the repo models faults, at five blast radii:

* **thread** — the paper's own §5.3/§5.4 model: pseudo-threads inside one
  sweep delay or crash-stop; surviving capacity re-covers their blocks on
  later sweeps.  :class:`ThreadFaultDomain` wraps the deterministic
  :class:`~repro.core.faults.FaultPlan` schedule (which stays the
  device-table generator) behind the domain interface.

* **shard** — one shard of a ``topology="sharded"`` session crashes or
  stalls mid-drive.  Recovery generalizes the paper's helping mechanism to
  shards: the surviving shards re-mark the dead shard's un-converged
  row-blocks as affected (their identities come from the runtime's slot
  tables) and drive them to convergence; a *permanent* loss additionally
  re-partitions the vertex space elastically onto the surviving mesh
  (:meth:`repro.core.distributed.DistRuntime.shrink`).
  :class:`ShardFaultDomain` is the deterministic injection schedule.

* **process** — crash-stop of the whole job.  Recovery is durability:
  a :class:`~repro.ckpt.checkpoint.SessionStore` holds atomic rank
  checkpoints plus a write-ahead log of applied batches;
  ``PageRankSession.restore`` replays the WAL through the normal
  zero-retrace hot path.  :class:`ProcessFaultDomain` carries the
  store + checkpoint cadence.

* **session** — one serving slot of a :class:`~repro.api.PageRankService`
  goes stuck, slow, or dead while the other slots keep serving.  Detection
  is by heartbeat (:class:`SlotHeartbeat`: every dispatch beats; a busy
  slot whose beat goes stale past the serving config's
  ``heartbeat_timeout_s`` is stuck); recovery drains the slot's queued
  batches to a session respawned through the process domain's
  ``failover()`` path.  :class:`SessionFault` is the deterministic
  injection schedule (kill or stall a slot after K dispatches) the
  chaos-under-load tests use.

* **corruption** — *silent* damage to live state: a flipped bit in the
  rank vector / tile pool / slot tables / operand mirrors, a torn or
  duplicated operand scatter, or corrupted host bookkeeping.  Unlike the
  four domains above, nothing announces the failure — detection is the
  integrity subsystem (`core/integrity.py`: fused invariant checks on
  every drive plus checksum scrubbing), and recovery is a three-rung
  ladder (frontier re-mark via the paper's helping path → rebuild from
  host slot tables → checkpoint+WAL restore).  :class:`CorruptionFault`
  / :class:`CorruptionFaultDomain` are the deterministic injection
  schedule the chaos harness (`core/chaos.py`) composes with the other
  domains.

Every recovery, in any domain, appends a :class:`RecoveryRecord` that
``session.report()`` / ``service.report()`` surface, so recovery time and
replayed work are observable wherever the fault happened.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.core.faults import NO_FAULTS, FaultPlan  # noqa: F401 (re-export)

DOMAINS = ("thread", "shard", "process", "session", "corruption")


@dataclasses.dataclass(frozen=True)
class RecoveryRecord:
    """One completed recovery, in any domain."""
    domain: str                    # "thread" | "shard" | "process"
    batch_index: int               # session batch the fault hit (-1: restore)
    wall_time_s: float             # detection → recovered
    description: str = ""
    # -- shard domain ---------------------------------------------------------
    shard: Optional[int] = None
    permanent: Optional[bool] = None
    helped_vertices: int = 0       # un-converged rows surviving shards took
    recovery_sweeps: int = 0
    # -- process domain -------------------------------------------------------
    replayed_batches: int = 0
    # -- session domain (service watchdog) ------------------------------------
    stream: Optional[int] = None   # service slot index the fault hit
    kind: Optional[str] = None     # "dead" | "stuck"
    drained_requests: int = 0      # queued batches re-routed to the respawn
    # -- corruption domain ----------------------------------------------------
    rung: Optional[str] = None     # "frontier" | "rebuild" | "restore"
    check: Optional[str] = None    # the integrity check that detected it

    def to_dict(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


class FaultDomain:
    """Base interface: a named blast radius with an injection schedule.
    Concrete domains are plain configuration objects — the session/runtime
    layers own the actual failure handling and call back into them."""

    name: str = "?"

    def validate_for(self, *, topology: str) -> None:
        """Raise when the domain cannot apply to a session topology."""


class ThreadFaultDomain(FaultDomain):
    """Pseudo-thread delays/crashes inside one sweep (paper §5.3, §5.4).

    Wraps a :class:`~repro.core.faults.FaultPlan` — the plan remains the
    deterministic per-(thread, sweep) schedule and device-table generator;
    the domain is how it enters :class:`~repro.api.config.EngineConfig`
    (``fault_domain=ThreadFaultDomain(plan)`` is equivalent to the legacy
    ``faults=plan``).  Recovery needs no extra machinery: unprocessed
    blocks keep their convergence flags set and surviving capacity
    re-covers them on later sweeps."""

    name = "thread"

    def __init__(self, plan: Optional[FaultPlan] = None, **plan_kw):
        if plan is not None and plan_kw:
            raise ValueError("pass a FaultPlan or FaultPlan kwargs, "
                             "not both")
        self.plan = plan if plan is not None else FaultPlan(**plan_kw)
        if not hasattr(self.plan, "device_tables"):
            raise ValueError("ThreadFaultDomain needs a FaultPlan "
                             "(.device_tables())")

    def validate_for(self, *, topology: str) -> None:
        if topology == "sharded":
            raise ValueError(
                "thread-domain fault simulation is single-device (pseudo-"
                "threads inside one sweep); sharded sessions take "
                "ShardFaultDomain")


@dataclasses.dataclass(frozen=True)
class ShardFault:
    """One scheduled shard failure: shard ``shard`` stops participating
    after ``at_sweep`` sweeps of the next drive.  ``permanent=True`` is
    crash-stop (the mesh shrinks around it); ``False`` is a transient
    stall (the shard rejoins after the drive — the straggler case)."""
    shard: int
    at_sweep: int = 1
    permanent: bool = True


class ShardFaultDomain(FaultDomain):
    """Deterministic shard-crash injection for ``topology="sharded"``
    sessions.  Faults queue FIFO; each ``update`` consumes at most one.
    The session performs the recovery (helping + optional elastic
    re-partition) and logs a :class:`RecoveryRecord`."""

    name = "shard"

    def __init__(self, faults: Optional[List[ShardFault]] = None):
        self._pending: List[ShardFault] = list(faults or [])

    def inject(self, shard: int, *, at_sweep: int = 1,
               permanent: bool = True) -> ShardFault:
        f = ShardFault(shard=int(shard), at_sweep=int(at_sweep),
                       permanent=bool(permanent))
        self._pending.append(f)
        return f

    def pop_pending(self) -> Optional[ShardFault]:
        return self._pending.pop(0) if self._pending else None

    def clone(self) -> "ShardFaultDomain":
        """Independent copy of the schedule.  Sessions consume their OWN
        clone: the domain rides on a frozen (shareable) ``EngineConfig``,
        and two sessions popping one shared ``_pending`` list would steal
        each other's faults."""
        return ShardFaultDomain(list(self._pending))

    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def pending_faults(self) -> List[ShardFault]:
        return list(self._pending)

    def validate_for(self, *, topology: str) -> None:
        if topology != "sharded":
            raise ValueError(
                "ShardFaultDomain requires topology='sharded' (the shard "
                "blast radius only exists on a device mesh)")


class ProcessFaultDomain(FaultDomain):
    """Crash-stop of the whole job.  There is nothing to *inject* in-
    process — the failure is the process dying — so this domain is pure
    recovery configuration: the durable store the session writes through
    and the checkpoint cadence.  Constructed **internally** by durable
    sessions (``EngineConfig(durability="wal")`` + ``store_dir=``); it is
    not a valid ``fault_domain=`` config value."""

    name = "process"

    def __init__(self, store: Any, *, checkpoint_interval: int = 16):
        self.store = store
        self.checkpoint_interval = int(checkpoint_interval)
        if self.checkpoint_interval < 1:
            raise ValueError("checkpoint_interval must be >= 1")

    def validate_for(self, *, topology: str) -> None:
        raise ValueError(
            "ProcessFaultDomain is constructed internally by durable "
            "sessions — configure the process domain with "
            "EngineConfig(durability='wal', checkpoint_interval=…) plus "
            "store_dir= at session construction, not via fault_domain=")


@dataclasses.dataclass(frozen=True)
class SessionFault:
    """One scheduled serving-slot failure, consumed by the service's
    dispatcher: after slot ``stream`` completes ``after_dispatches``
    dispatches, the NEXT dispatch hits the fault.  ``kind="dead"`` closes
    the slot's session before the update touches any state (crash-stop of
    the slot — the honest analogue of the session object dying, and safe
    to re-drain because nothing was WAL-logged); ``kind="stuck"`` stalls
    the dispatching worker for ``stall_s`` seconds *before* the update, so
    the heartbeat goes stale while the slot holds work."""
    stream: int
    after_dispatches: int = 0
    kind: str = "dead"
    stall_s: float = 0.0

    def __post_init__(self):
        if self.kind not in ("dead", "stuck"):
            raise ValueError(f"kind={self.kind!r} invalid; expected "
                             "'dead' or 'stuck'")
        if self.kind == "stuck" and self.stall_s <= 0:
            raise ValueError("kind='stuck' needs stall_s > 0")


#: Injectable silent-corruption kinds (see ``session.inject_corruption``):
#: ``rank``  — exponent-range bit flip in one live rank value
#: ``tile``  — bit flip in one live tile of the pull-matrix pool
#: ``slot``  — bit flip in the slot tables (a tile_cols column id)
#: ``mirror``— perturb one operand mirror (rb_in) on device
#: ``scatter_drop`` / ``scatter_dup`` — the NEXT update's operand-mirror
#:             scatter is silently dropped / applied twice (torn scatter)
#: ``graph`` — corrupt the host graph's edge list (host truth itself),
#:             so only the durable store can repair
CORRUPTION_KINDS = ("rank", "tile", "slot", "mirror",
                    "scatter_drop", "scatter_dup", "graph")


@dataclasses.dataclass(frozen=True)
class CorruptionFault:
    """One scheduled silent corruption.  ``seed`` deterministically picks
    the injection site (vertex, tile, bit); ``index`` pins it explicitly
    instead when not None."""
    kind: str
    index: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        if self.kind not in CORRUPTION_KINDS:
            raise ValueError(f"kind={self.kind!r} invalid; expected one "
                             f"of {list(CORRUPTION_KINDS)}")


class CorruptionFaultDomain(FaultDomain):
    """Deterministic silent-corruption injection for streaming sessions.
    Faults queue FIFO; each ``update`` consumes at most one and applies
    it to live state *before* driving, so the drive's fused invariant
    checks (or the next scrub) must detect it.  The session performs the
    repair (the integrity ladder, `core/integrity.py`) and logs a
    :class:`RecoveryRecord(domain="corruption")`."""

    name = "corruption"

    def __init__(self, faults: Optional[List[CorruptionFault]] = None):
        self._pending: List[CorruptionFault] = list(faults or [])

    def inject(self, kind: str, *, index: Optional[int] = None,
               seed: int = 0) -> CorruptionFault:
        f = CorruptionFault(kind=str(kind), index=index, seed=int(seed))
        self._pending.append(f)
        return f

    def pop_pending(self) -> Optional[CorruptionFault]:
        return self._pending.pop(0) if self._pending else None

    def clone(self) -> "CorruptionFaultDomain":
        """Independent copy of the schedule (same contract as
        :meth:`ShardFaultDomain.clone`: the domain rides on a frozen
        shareable config, so each session consumes its own clone)."""
        return CorruptionFaultDomain(list(self._pending))

    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def pending_faults(self) -> List[CorruptionFault]:
        return list(self._pending)

    def validate_for(self, *, topology: str) -> None:
        if topology != "single":
            raise ValueError(
                "CorruptionFaultDomain instruments the single-device "
                "streaming path (device mirrors + tile pool); sharded "
                "sessions take ShardFaultDomain")


class SlotHeartbeat:
    """Per-slot liveness bookkeeping for the service watchdog.

    A worker ``beat()``s when it picks up work and when it finishes;
    ``busy_since`` stays set for the whole dispatch.  ``stale(timeout)``
    is the stuck-slot predicate: busy AND no beat for ``timeout`` seconds
    — an idle slot is never stale, however long it idles."""

    def __init__(self):
        self._last: Dict[int, float] = {}
        self._busy_since: Dict[int, float] = {}

    def beat(self, slot: int) -> None:
        self._last[slot] = time.perf_counter()

    def busy(self, slot: int) -> None:
        now = time.perf_counter()
        self._busy_since[slot] = now
        self._last[slot] = now

    def idle(self, slot: int) -> None:
        self._busy_since.pop(slot, None)
        self._last[slot] = time.perf_counter()

    def is_busy(self, slot: int) -> bool:
        return slot in self._busy_since

    def stale(self, slot: int, timeout_s: float) -> bool:
        if slot not in self._busy_since:
            return False
        return (time.perf_counter() - self._last.get(slot, 0.0)) > timeout_s

    def age_s(self, slot: int) -> float:
        last = self._last.get(slot)
        return 0.0 if last is None else time.perf_counter() - last


def resolve_thread_plan(faults: Any, fault_domain: Any) -> Optional[Any]:
    """The engine-level :class:`FaultPlan` implied by a config's
    ``faults`` / ``fault_domain`` pair (engines consume plans, not
    domains)."""
    if faults is not None:
        return faults
    if isinstance(fault_domain, ThreadFaultDomain):
        return fault_domain.plan
    return None
