"""Unified fault domains: thread, shard, and process faults behind one
recovery abstraction (docs/FAULTS.md).

The paper's claim is that DF_LF "withstands random thread delays and
crashes"; the non-blocking PageRank line of work argues fault tolerance
must be a property of the *whole pipeline*.  This module is the one place
the repo models faults, at three blast radii:

* **thread** — the paper's own §5.3/§5.4 model: pseudo-threads inside one
  sweep delay or crash-stop; surviving capacity re-covers their blocks on
  later sweeps.  :class:`ThreadFaultDomain` wraps the deterministic
  :class:`~repro.core.faults.FaultPlan` schedule (which stays the
  device-table generator) behind the domain interface.

* **shard** — one shard of a ``topology="sharded"`` session crashes or
  stalls mid-drive.  Recovery generalizes the paper's helping mechanism to
  shards: the surviving shards re-mark the dead shard's un-converged
  row-blocks as affected (their identities come from the runtime's slot
  tables) and drive them to convergence; a *permanent* loss additionally
  re-partitions the vertex space elastically onto the surviving mesh
  (:meth:`repro.core.distributed.DistRuntime.shrink`).
  :class:`ShardFaultDomain` is the deterministic injection schedule.

* **process** — crash-stop of the whole job.  Recovery is durability:
  a :class:`~repro.ckpt.checkpoint.SessionStore` holds atomic rank
  checkpoints plus a write-ahead log of applied batches;
  ``PageRankSession.restore`` replays the WAL through the normal
  zero-retrace hot path.  :class:`ProcessFaultDomain` carries the
  store + checkpoint cadence.

Every recovery, in any domain, appends a :class:`RecoveryRecord` that
``session.report()`` / ``service.report()`` surface, so recovery time and
replayed work are observable wherever the fault happened.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

from repro.core.faults import NO_FAULTS, FaultPlan  # noqa: F401 (re-export)

DOMAINS = ("thread", "shard", "process")


@dataclasses.dataclass(frozen=True)
class RecoveryRecord:
    """One completed recovery, in any domain."""
    domain: str                    # "thread" | "shard" | "process"
    batch_index: int               # session batch the fault hit (-1: restore)
    wall_time_s: float             # detection → recovered
    description: str = ""
    # -- shard domain ---------------------------------------------------------
    shard: Optional[int] = None
    permanent: Optional[bool] = None
    helped_vertices: int = 0       # un-converged rows surviving shards took
    recovery_sweeps: int = 0
    # -- process domain -------------------------------------------------------
    replayed_batches: int = 0

    def to_dict(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


class FaultDomain:
    """Base interface: a named blast radius with an injection schedule.
    Concrete domains are plain configuration objects — the session/runtime
    layers own the actual failure handling and call back into them."""

    name: str = "?"

    def validate_for(self, *, topology: str) -> None:
        """Raise when the domain cannot apply to a session topology."""


class ThreadFaultDomain(FaultDomain):
    """Pseudo-thread delays/crashes inside one sweep (paper §5.3, §5.4).

    Wraps a :class:`~repro.core.faults.FaultPlan` — the plan remains the
    deterministic per-(thread, sweep) schedule and device-table generator;
    the domain is how it enters :class:`~repro.api.config.EngineConfig`
    (``fault_domain=ThreadFaultDomain(plan)`` is equivalent to the legacy
    ``faults=plan``).  Recovery needs no extra machinery: unprocessed
    blocks keep their convergence flags set and surviving capacity
    re-covers them on later sweeps."""

    name = "thread"

    def __init__(self, plan: Optional[FaultPlan] = None, **plan_kw):
        if plan is not None and plan_kw:
            raise ValueError("pass a FaultPlan or FaultPlan kwargs, "
                             "not both")
        self.plan = plan if plan is not None else FaultPlan(**plan_kw)
        if not hasattr(self.plan, "device_tables"):
            raise ValueError("ThreadFaultDomain needs a FaultPlan "
                             "(.device_tables())")

    def validate_for(self, *, topology: str) -> None:
        if topology == "sharded":
            raise ValueError(
                "thread-domain fault simulation is single-device (pseudo-"
                "threads inside one sweep); sharded sessions take "
                "ShardFaultDomain")


@dataclasses.dataclass(frozen=True)
class ShardFault:
    """One scheduled shard failure: shard ``shard`` stops participating
    after ``at_sweep`` sweeps of the next drive.  ``permanent=True`` is
    crash-stop (the mesh shrinks around it); ``False`` is a transient
    stall (the shard rejoins after the drive — the straggler case)."""
    shard: int
    at_sweep: int = 1
    permanent: bool = True


class ShardFaultDomain(FaultDomain):
    """Deterministic shard-crash injection for ``topology="sharded"``
    sessions.  Faults queue FIFO; each ``update`` consumes at most one.
    The session performs the recovery (helping + optional elastic
    re-partition) and logs a :class:`RecoveryRecord`."""

    name = "shard"

    def __init__(self, faults: Optional[List[ShardFault]] = None):
        self._pending: List[ShardFault] = list(faults or [])

    def inject(self, shard: int, *, at_sweep: int = 1,
               permanent: bool = True) -> ShardFault:
        f = ShardFault(shard=int(shard), at_sweep=int(at_sweep),
                       permanent=bool(permanent))
        self._pending.append(f)
        return f

    def pop_pending(self) -> Optional[ShardFault]:
        return self._pending.pop(0) if self._pending else None

    def clone(self) -> "ShardFaultDomain":
        """Independent copy of the schedule.  Sessions consume their OWN
        clone: the domain rides on a frozen (shareable) ``EngineConfig``,
        and two sessions popping one shared ``_pending`` list would steal
        each other's faults."""
        return ShardFaultDomain(list(self._pending))

    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def pending_faults(self) -> List[ShardFault]:
        return list(self._pending)

    def validate_for(self, *, topology: str) -> None:
        if topology != "sharded":
            raise ValueError(
                "ShardFaultDomain requires topology='sharded' (the shard "
                "blast radius only exists on a device mesh)")


class ProcessFaultDomain(FaultDomain):
    """Crash-stop of the whole job.  There is nothing to *inject* in-
    process — the failure is the process dying — so this domain is pure
    recovery configuration: the durable store the session writes through
    and the checkpoint cadence.  Constructed **internally** by durable
    sessions (``EngineConfig(durability="wal")`` + ``store_dir=``); it is
    not a valid ``fault_domain=`` config value."""

    name = "process"

    def __init__(self, store: Any, *, checkpoint_interval: int = 16):
        self.store = store
        self.checkpoint_interval = int(checkpoint_interval)
        if self.checkpoint_interval < 1:
            raise ValueError("checkpoint_interval must be >= 1")

    def validate_for(self, *, topology: str) -> None:
        raise ValueError(
            "ProcessFaultDomain is constructed internally by durable "
            "sessions — configure the process domain with "
            "EngineConfig(durability='wal', checkpoint_interval=…) plus "
            "store_dir= at session construction, not via fault_domain=")


def resolve_thread_plan(faults: Any, fault_domain: Any) -> Optional[Any]:
    """The engine-level :class:`FaultPlan` implied by a config's
    ``faults`` / ``fault_domain`` pair (engines consume plans, not
    domains)."""
    if faults is not None:
        return faults
    if isinstance(fault_domain, ThreadFaultDomain):
        return fault_domain.plan
    return None
