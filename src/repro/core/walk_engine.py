"""Monte Carlo walk engine — sweep-free (personalized) PageRank.

Implements the Bahmani et al. *Fast Incremental and Personalized PageRank*
scheme on top of the repo's incremental-graph discipline: the engine state
is ``R`` fixed-length-capped random-walk segments per vertex, resident on
device in capacity-padded buffers, plus a per-vertex visit counter folded
incrementally.  There is no sweep loop anywhere:

  * **estimate** — a walk from ``v`` continues with probability ``alpha``
    and picks a uniform out-neighbor (the snapshot's implicit self-loop
    included, so the stationary target matches the pull engines' graph)
    until it terminates or hits the ``L``-step cap.  With ``X_u`` = total
    visits to ``u`` over all ``n*R`` walks, ``PR(u) ≈ X_u (1-α) / (nR)``;
    restricting to the walks started at a seed set ``S`` gives
    ``PPR_S(u) ≈ X_u^S (1-α) / (|S| R)``.  Both are O(read) queries over
    device-resident state.
  * **update** — an edge delta only changes the trajectories of walks that
    *visit a touched vertex* (a source endpoint of an effective edge
    change): every per-walk random draw is a pure function of
    ``(walk_seed, walk id)`` and adjacency rows are kept **sorted**, so an
    untouched walk is bit-identical under the old and new graph, and
    delete+reinsert of the same edge restores the walk buffers exactly.
    A host-side reverse index (vertex → walks visiting it) selects the
    touched walks in O(touched-walk mass); the regeneration batch is
    padded onto a doubling ladder (same discipline as the tile pool) and
    rebuilt by one bucketed scatter — never a global regeneration, which
    :meth:`WalkState.apply_batch` asserts.

Adjacency lives in CSR-style per-vertex slabs (``[n+1, cap]`` with a
sentinel row/values at ``n``), patched O(batch) per delta on the host twin
and scattered to the device mirror at a bucketed batch width.  The slab
width ``cap`` sits on its own capacity ladder and widens (one legitimate
bucket compile) when a vertex outgrows it.

Registered through :mod:`repro.api.registry` as the builtin ``walk``
engine with ``supports={"ppr"}`` — the only engine that accepts
personalization; the config layer rejects walk fields on every other
engine (:class:`repro.api.registry.CapabilityError`).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Set, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import blocked as blk
from repro.core.graph import GraphSnapshot, HostGraph
from repro.kernels.block_spmv import ops

# Capacity-ladder bases (doubling discipline; see ops.capacity_bucket).
WALK_BATCH_BUCKET = 64     # regeneration scatter-batch floor
ADJ_SLOT_BASE = 8          # per-vertex adjacency slab-width floor

# Defaults EngineConfig resolves its None walk fields to.
DEFAULT_WALKS_PER_VERTEX = 16
DEFAULT_WALK_LENGTH = 48
DEFAULT_WALK_SEED = 0


# ---------------------------------------------------------------------------
# jitted kernels (shapes ride the capacity ladders; cache growth outside a
# first bucket visit is a retrace bug, counted via cache_size())
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("R",))
def _regen_step(walks, counts, adj, deg, wids, alpha, key, *, R: int):
    """Regenerate the walks named by ``wids`` and fold the visit counters.

    ``walks [n*R+1, L]`` i32 vertex ids (sentinel ``n`` past termination;
    row ``n*R`` is the inert scratch row padding scatters land on);
    ``counts [n+1]`` i32 (slot ``n`` absorbs sentinel visits and is reset);
    ``adj [n+1, cap]`` / ``deg [n+1]`` the adjacency slabs; ``wids [B]``
    i32 walk ids, padded with ``n*R``.  Each walk's draws come from
    ``fold_in(key, wid)`` only, so a trajectory is a pure function of
    (seed, walk id, adjacency rows it visits) — the delta-localization
    property rests on exactly this.
    """
    L = walks.shape[1]
    n = counts.shape[0] - 1
    nr = walks.shape[0] - 1
    sent = jnp.int32(n)
    starts = jnp.where(wids < nr, wids // R, nr // R).astype(jnp.int32)
    old = walks[wids]                                        # [B, L]
    keys = jax.vmap(lambda w: jax.random.fold_in(key, w))(wids)
    u = jax.vmap(lambda k: jax.random.uniform(k, (2, L)))(keys)
    r_term = jnp.swapaxes(u[:, 0, :], 0, 1)                  # [L, B]
    r_nbr = jnp.swapaxes(u[:, 1, :], 0, 1)

    def step(carry, rnd):
        cur, alive = carry
        rt, rn = rnd
        d = deg[cur]
        # uniform over the d real out-neighbors plus the implicit
        # self-loop (index d) — matches the snapshot's self-loop semantics
        j = jnp.minimum((rn * (d + 1).astype(rn.dtype)).astype(jnp.int32),
                        d)
        nxt = jnp.where(j == d, cur, adj[cur, j])
        alive = alive & (rt < alpha)
        cur = jnp.where(alive, nxt, cur)
        return (cur, alive), jnp.where(alive, nxt, sent)

    (_, _), tail = lax.scan(step, (starts, starts < sent),
                            (r_term[:L - 1], r_nbr[:L - 1]))
    traj = jnp.concatenate([starts[:, None],
                            jnp.swapaxes(tail, 0, 1)], axis=1)
    clip = lambda a: jnp.minimum(a, sent).ravel()            # noqa: E731
    counts = (counts.at[clip(old)].add(-1)
                    .at[clip(traj)].add(1)
                    .at[n].set(0))
    return walks.at[wids].set(traj), counts


@jax.jit
def _patch_rows(adj, deg, idx, rows, degs):
    """Scatter patched adjacency rows (bucketed; padding targets the
    sentinel row ``n`` with sentinel content, which is a no-op)."""
    return adj.at[idx].set(rows), deg.at[idx].set(degs)


@partial(jax.jit, static_argnames=("R", "dtype"))
def _ppr_full(walks, seeds, alpha, *, R: int, dtype):
    """Personalized PageRank estimate for a uniform restart over ``seeds``:
    fold the visit counts of the seeds' own walks — O(|S|·R·L) device
    work, independent of the batch history."""
    L = walks.shape[1]
    nr = walks.shape[0] - 1
    n = nr // R
    s = seeds.shape[0]
    rows = (seeds.astype(jnp.int32)[:, None] * R
            + jnp.arange(R, dtype=jnp.int32)[None, :]).reshape(-1)
    t = walks[rows]                                          # [s*R, L]
    visits = jnp.zeros(n + 1, jnp.int32).at[
        jnp.minimum(t, jnp.int32(n)).ravel()].add(1)[:n]
    scale = (1.0 - alpha).astype(dtype) / (s * R)
    return visits.astype(dtype) * scale


@partial(jax.jit, static_argnames=("R", "k", "dtype"))
def _ppr_topk(walks, seeds, alpha, *, R: int, k: int, dtype):
    ppr = _ppr_full(walks, seeds, alpha, R=R, dtype=dtype)
    return lax.top_k(ppr, k)


@partial(jax.jit, static_argnames=("R", "dtype"))
def _pr_estimate(counts, alpha, *, R: int, dtype):
    n = counts.shape[0] - 1
    scale = (1.0 - alpha).astype(dtype) / (n * R)
    return counts[:n].astype(dtype) * scale


def cache_size() -> int:
    """Total jit-cache entries of the walk hot-path kernels (the walk
    engine's analog of the fused driver's cache; query kernels are
    excluded — they legitimately compile per (|S|, k) shape)."""
    try:
        return (int(_regen_step._cache_size())
                + int(_patch_rows._cache_size()))
    except Exception:           # pragma: no cover - older jax fallback
        return -1


# ---------------------------------------------------------------------------
# walk store
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WalkUpdateStats:
    """Per-delta localization accounting (the acceptance instrument)."""
    touched_vertices: int       # distinct src endpoints of effective edges
    touched_walk_mass: int      # Σ_u |walks visiting u| over touched u
    regenerated_walks: int      # |union| — walks actually rebuilt
    total_walks: int            # n * R
    steps: int                  # walk steps recomputed (work metric)
    batch_bucket: int           # padded regeneration width (ladder bucket)
    adj_cap: int                # adjacency slab width after the batch
    new_bucket: bool            # first visit to a ladder bucket this batch


class WalkState:
    """Device-resident Monte Carlo walk store over an incremental
    adjacency.  One instance backs one walk-engine session; ``fork()``
    shares the (immutable) device buffers and copies the host twins."""

    def __init__(self, hg: HostGraph, *,
                 R: int = DEFAULT_WALKS_PER_VERTEX,
                 L: int = DEFAULT_WALK_LENGTH,
                 seed: int = DEFAULT_WALK_SEED,
                 alpha: float = 0.85,
                 dtype=np.float64):
        if int(R) < 1:
            raise ValueError(f"walks_per_vertex={R} must be >= 1")
        if int(L) < 2:
            raise ValueError(f"walk_length={L} must be >= 2 (a walk is its "
                             "start vertex plus at least one step slot)")
        self.n = int(hg.n)
        self.R = int(R)
        self.L = int(L)
        self.seed = int(seed)
        self.alpha = float(alpha)
        self.dtype = np.dtype(dtype)
        self._key = jax.random.PRNGKey(self.seed)
        self._alpha_op = jnp.float32(self.alpha)
        n, nr = self.n, self.n * self.R

        # -- adjacency slabs: host truth + device mirror ------------------
        # rows are kept SORTED by destination id so a delete+reinsert of
        # the same edge restores the row (and thus every walk through it)
        # bit-for-bit — hg.edges is already (src, dst)-sorted
        src = hg.edges[:, 0].astype(np.int64)
        dst = hg.edges[:, 1].astype(np.int64)
        degs = np.bincount(src, minlength=n).astype(np.int64) if hg.m \
            else np.zeros(n, np.int64)
        self._cap = int(ops.capacity_bucket(max(int(degs.max()) if hg.m
                                                else 1, 1), ADJ_SLOT_BASE))
        self._adj_host = np.full((n + 1, self._cap), n, np.int32)
        self._deg_host = np.zeros(n + 1, np.int32)
        if hg.m:
            col = np.arange(hg.m) - np.repeat(np.cumsum(degs) - degs, degs)
            self._adj_host[src, col] = dst.astype(np.int32)
            self._deg_host[:n] = degs.astype(np.int32)
        self.adj = jnp.asarray(self._adj_host)
        self.deg = jnp.asarray(self._deg_host)

        # -- walk buffers + counters: generate everything once ------------
        self.walks = jnp.full((nr + 1, self.L), n, jnp.int32)
        self.counts = jnp.zeros(n + 1, jnp.int32)
        self.walks, self.counts = _regen_step(
            self.walks, self.counts, self.adj, self.deg,
            jnp.arange(nr, dtype=jnp.int32), self._alpha_op, self._key,
            R=self.R)
        self._buckets: Set[Tuple] = set()   # ladder buckets seen post-init
        self._build_index()

    # -- reverse index (host): vertex -> set of walk ids visiting it -------
    def _build_index(self) -> None:
        nr = self.n * self.R
        w = np.asarray(self.walks[:nr])
        ids = np.repeat(np.arange(nr, dtype=np.int64), self.L)
        vs = w.ravel().astype(np.int64)
        keep = vs < self.n
        pairs = np.unique(vs[keep] * nr + ids[keep])
        self._index: List[Set[int]] = [set() for _ in range(self.n)]
        for v, wid in zip((pairs // nr).tolist(), (pairs % nr).tolist()):
            self._index[v].add(wid)

    def _see_bucket(self, key: Tuple) -> bool:
        """Record a ladder-bucket visit; True when it is the first."""
        new = key not in self._buckets
        self._buckets.add(key)
        return new

    # -- O(batch) delta application ----------------------------------------
    def apply_batch(self, dels: np.ndarray, ins: np.ndarray
                    ) -> WalkUpdateStats:
        """Apply one **effective** edge batch (``core.incremental.
        effective_batch`` output: every edge genuinely changes the graph)
        and regenerate exactly the walks passing through touched vertices.
        """
        n, R, nr = self.n, self.R, self.n * self.R
        dels = np.asarray(dels, np.int64).reshape(-1, 2)
        ins = np.asarray(ins, np.int64).reshape(-1, 2)
        touched = np.unique(np.concatenate([dels[:, 0], ins[:, 0]])) \
            if (len(dels) + len(ins)) else np.zeros(0, np.int64)
        new_bucket = False

        if touched.size:
            # host patch of the touched rows (sorted-set semantics)
            rows_new = []
            widest = 0
            for uu in touched.tolist():
                row = self._adj_host[uu, :self._deg_host[uu]].astype(
                    np.int64)
                du = dels[dels[:, 0] == uu, 1]
                iu = ins[ins[:, 0] == uu, 1]
                if du.size:
                    row = np.setdiff1d(row, du)
                if iu.size:
                    row = np.union1d(row, iu)
                rows_new.append(row)
                widest = max(widest, row.size)
            if widest > self._cap:      # slab ladder: widen (one compile)
                self._widen(int(ops.capacity_bucket(widest, ADJ_SLOT_BASE)))
                new_bucket = True
            for uu, row in zip(touched.tolist(), rows_new):
                self._adj_host[uu, :] = n
                self._adj_host[uu, :row.size] = row.astype(np.int32)
                self._deg_host[uu] = row.size
            # bucketed device scatter of just the touched rows
            b = int(ops.capacity_bucket(touched.size,
                                        ops.DELTA_BATCH_BUCKET))
            idx = np.full(b, n, np.int32)
            idx[:touched.size] = touched.astype(np.int32)
            vals = np.full((b, self._cap), n, np.int32)
            vals[:touched.size] = self._adj_host[touched]
            dvals = np.zeros(b, np.int32)
            dvals[:touched.size] = self._deg_host[touched]
            if self._see_bucket(("adj", b, self._cap)):
                new_bucket = True
            self.adj, self.deg = _patch_rows(
                self.adj, self.deg, jnp.asarray(idx), jnp.asarray(vals),
                jnp.asarray(dvals))

        # touched walks via the reverse index — never a buffer scan
        wset: Set[int] = set()
        mass = 0
        for uu in touched.tolist():
            s = self._index[uu]
            mass += len(s)
            wset |= s
        regen = len(wset)
        if regen > mass:        # structurally impossible; guard regardless
            raise AssertionError(
                f"regenerated-walk count {regen} exceeds the touched-walk "
                f"mass {mass}: regeneration is no longer delta-localized")

        steps = 0
        b_pad = 0
        if regen:
            wids = np.fromiter(wset, np.int64, regen)
            wids.sort()
            b_pad = int(ops.capacity_bucket(regen, WALK_BATCH_BUCKET))
            wids_pad = np.full(b_pad, nr, np.int32)
            wids_pad[:regen] = wids.astype(np.int32)
            wdev = jnp.asarray(wids.astype(np.int32))
            old_rows = np.asarray(self.walks[wdev])
            if self._see_bucket(("regen", b_pad, self._cap)):
                new_bucket = True
            self.walks, self.counts = _regen_step(
                self.walks, self.counts, self.adj, self.deg,
                jnp.asarray(wids_pad), self._alpha_op, self._key, R=self.R)
            new_rows = np.asarray(self.walks[wdev])
            steps = int((new_rows < n).sum())
            for wid, orow, nrow in zip(wids.tolist(), old_rows, new_rows):
                for v in np.unique(orow).tolist():
                    if v < n:
                        self._index[v].discard(wid)
                for v in np.unique(nrow).tolist():
                    if v < n:
                        self._index[v].add(wid)
        return WalkUpdateStats(
            touched_vertices=int(touched.size), touched_walk_mass=mass,
            regenerated_walks=regen, total_walks=nr, steps=steps,
            batch_bucket=b_pad, adj_cap=self._cap, new_bucket=new_bucket)

    def _widen(self, cap_new: int) -> None:
        """Grow the adjacency slab width to the next ladder bucket."""
        wide = np.full((self.n + 1, cap_new), self.n, np.int32)
        wide[:, :self._cap] = self._adj_host
        self._adj_host = wide
        self._cap = cap_new
        self.adj = jnp.asarray(wide)

    @property
    def total_steps(self) -> int:
        """Live (non-sentinel) walk positions across every buffer — the
        total step count a full regeneration recomputes."""
        nr = self.n * self.R
        return int(np.asarray((self.walks[:nr] < self.n).sum()))

    # -- O(read) queries ----------------------------------------------------
    def pagerank(self) -> jnp.ndarray:
        """Global PR estimate [n] from the incrementally folded counters."""
        return _pr_estimate(self.counts, self._alpha_op, R=self.R,
                            dtype=self.dtype)

    def ppr(self, seeds) -> jnp.ndarray:
        """Full personalized-PageRank estimate [n] for a uniform restart
        over ``seeds`` (int array of vertex ids)."""
        s = jnp.asarray(np.asarray(seeds, np.int64).reshape(-1)
                        .astype(np.int32))
        return _ppr_full(self.walks, s, self._alpha_op, R=self.R,
                         dtype=self.dtype)

    def ppr_top_k(self, seeds, k: int):
        """(values, vertex ids) of the k highest PPR estimates."""
        s = jnp.asarray(np.asarray(seeds, np.int64).reshape(-1)
                        .astype(np.int32))
        return _ppr_topk(self.walks, s, self._alpha_op, R=self.R,
                         k=int(k), dtype=self.dtype)

    # -- lifecycle -----------------------------------------------------------
    def warmup(self) -> None:
        """Compile the hot-path kernels at the ladder base buckets with
        inert (all-padding) operands — state is untouched."""
        n, nr = self.n, self.n * self.R
        self.walks, self.counts = _regen_step(
            self.walks, self.counts, self.adj, self.deg,
            jnp.full(WALK_BATCH_BUCKET, nr, jnp.int32), self._alpha_op,
            self._key, R=self.R)
        self._buckets.add(("regen", WALK_BATCH_BUCKET, self._cap))
        b = int(ops.DELTA_BATCH_BUCKET)
        self.adj, self.deg = _patch_rows(
            self.adj, self.deg, jnp.full(b, n, jnp.int32),
            jnp.full((b, self._cap), n, jnp.int32), jnp.zeros(b, jnp.int32))
        self._buckets.add(("adj", b, self._cap))

    def fork(self) -> "WalkState":
        """Share the immutable device buffers; copy the host-mutable twins
        (adjacency truth + reverse index + bucket set)."""
        new = object.__new__(WalkState)
        new.__dict__.update(self.__dict__)
        new._adj_host = self._adj_host.copy()
        new._deg_host = self._deg_host.copy()
        new._index = [s.copy() for s in self._index]
        new._buckets = set(self._buckets)
        return new


# ---------------------------------------------------------------------------
# repro.api engine adapter (Engine protocol; loaded lazily by the registry)
# ---------------------------------------------------------------------------

class WalkEngine:
    """Registry adapter for the Monte Carlo walk engine — the sweep-free
    estimator.  ``supports`` declares the personalization capability the
    config layer gates walk fields on; the snapshot-level ``run`` builds a
    throwaway walk store at the default (R, L, seed) and returns the
    global estimate (sessions use :class:`WalkState` directly through the
    walk mode and carry the configured parameters)."""

    name = "walk"
    fault_domains = ("process",)
    supports = frozenset({"ppr"})

    def run(self, g, R0, affected0, *, mode="lf", expand=True, alpha=0.85,
            tau=1e-10, tau_f=None, max_iterations=500, faults=None,
            tile=512, active_policy="affected", mat=None, aux=None,
            backend=None, interpret=None, shards=None):
        from repro.api.registry import (reject_shard_spec,
                                        reject_tile_operands)
        reject_tile_operands(self.name, mat, aux, backend)
        reject_shard_spec(self.name, shards)
        if faults is not None:
            raise ValueError(
                "the walk engine hosts no thread fault domain (declares "
                f"{self.fault_domains}); faults must be None")
        src, dst = g.in_edges_host()
        keep = src != dst           # snapshot self-loops are re-implied
        hg = HostGraph(g.n, np.stack([src[keep], dst[keep]], 1))
        st = WalkState(hg, alpha=alpha, dtype=np.dtype(R0.dtype))
        ranks = jnp.zeros((g.n_pad,), st.dtype).at[:g.n].set(st.pagerank())
        est_len = min(1.0 / (1.0 - alpha), float(st.L))
        stats = blk.SweepStats(
            sweeps=1, iterations=1, converged=True,
            edges_processed=int(g.n * st.R * est_len))
        return jax.block_until_ready(ranks), stats


def as_engine() -> WalkEngine:
    return WalkEngine()
