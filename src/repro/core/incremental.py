"""Dynamic-Frontier generalized to GNN vertex programs (beyond-paper).

DESIGN.md §Arch-applicability: the paper's DF technique is a *vertex-program*
acceleration, not PageRank-specific.  Its two ingredients —
  (1) initial marking of update sources' out-neighborhoods, and
  (2) incremental expansion gated by a frontier tolerance τ_f —
apply verbatim to GNN inference on dynamic graphs: after a batch of edge
updates, only nodes whose embeddings can change need recomputation, and a
node whose embedding moved less than τ_f cuts off its receptive-field cone.

``incremental_gnn_update`` re-embeds only the affected node set per layer,
expanding the frontier between layers exactly like DF expands between
PageRank iterations.  Exercised by examples/incremental_gnn.py and
tests/test_incremental.py; this is the "DF applies to the GNN family" path.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.gnn.common import GNNConfig, GraphBatch


def edge_update_sources(n_pad: int, deletions: np.ndarray,
                        insertions: np.ndarray) -> jnp.ndarray:
    """Indicator of update source vertices (both endpoints for undirected
    message passing: a changed edge changes BOTH endpoints' aggregations)."""
    ind = np.zeros(n_pad + 1, dtype=bool)
    for batch in (deletions, insertions):
        b = np.asarray(batch, np.int64).reshape(-1, 2)
        ind[np.minimum(b[:, 0], n_pad)] = True
        ind[np.minimum(b[:, 1], n_pad)] = True
    return jnp.asarray(ind[:n_pad])


def out_neighbors_or(g: GraphBatch, flags: jnp.ndarray) -> jnp.ndarray:
    """Nodes receiving at least one message from a flagged node."""
    f = jnp.concatenate([flags, jnp.zeros((1,), flags.dtype)])
    hit = jax.ops.segment_max(
        f[jnp.minimum(g.senders, g.n_pad)].astype(jnp.int32),
        g.receivers, num_segments=g.n_pad + 1)[:g.n_pad]
    return hit > 0


def incremental_gnn_update(
        layer_fns, g: GraphBatch, h0: jnp.ndarray,
        cached_layers, sources: jnp.ndarray, *, tau_f: float
) -> Tuple[jnp.ndarray, list, Dict[str, int]]:
    """Recompute a layered GNN after a graph update, DF-style.

    layer_fns[i](g, h) -> h'  — full-graph layer functions;
    cached_layers[i]          — pre-update activations per layer (i=0 input);
    sources                   — indicator of update-source nodes.

    Per layer: recompute only currently-affected nodes (others keep their
    cached activation), then expand the frontier to the out-neighbors of
    nodes whose activation moved more than τ_f — the DF gate.  Returns the
    new final activations, the refreshed cache, and work counters.
    """
    affected = out_neighbors_or(g, sources) | sources
    new_cache = [h0]
    h = h0
    stats = {"recomputed": 0, "total": 0}
    for i, fn in enumerate(layer_fns):
        full = fn(g, h)                       # masked cost model: a real
        # deployment computes only affected rows; on TPU the win is measured
        # in the affected-row count (stats) while XLA computes dense tiles.
        prev = cached_layers[i + 1]
        h_new = jnp.where(affected[:, None], full, prev)
        moved = affected & (
            jnp.max(jnp.abs(h_new - prev), axis=-1) > tau_f)
        stats["recomputed"] += int(affected.sum())
        stats["total"] += int(g.n_pad)
        affected = affected | out_neighbors_or(g, moved)
        new_cache.append(h_new)
        h = h_new
    return h, new_cache, stats


def full_gnn_layers(mod, params, cfg: GNNConfig):
    """Adapt a model-zoo family into per-layer closures for the incremental
    path (graphsage-style: h' = layer(h))."""
    if cfg.family != "graphsage":
        raise NotImplementedError(
            "incremental path is exercised on graphsage (mean aggregation "
            "is layer-local); other families need their edge state threaded")
    from repro.models.gnn import graphsage as GS
    from repro.models.gnn import common as C

    def make(i):
        def fn(g, h):
            neigh = C.scatter_mean(g, C.gather_src(g, h))
            return GS._layer(params, i, h, neigh)
        return fn

    return [make(i) for i in range(cfg.n_layers)]
