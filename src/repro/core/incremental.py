"""Incremental maintenance of dynamic-graph state (beyond-paper).

Two members:

* :class:`IncrementalPullMatrix` — keeps the fused Pallas engine's
  block-sparse pull matrix in sync with a dynamic edge stream by patching
  only the tiles each batch touches (``ops.apply_delta``), instead of the
  O(m) host rebuild per snapshot.  This is the state carrier that makes the
  ``engine="pallas"`` path incremental end-to-end: frontier-proportional
  *compute* per sweep and batch-proportional *build* per snapshot.

* ``incremental_gnn_update`` — DF generalized to GNN vertex programs
  (DESIGN.md §Arch-applicability): after a batch of edge updates only nodes
  whose embeddings can change are re-embedded, with τ_f cutting off the
  receptive-field cone.  Gated on the model zoo being importable (the GNN
  stack needs :mod:`repro.dist`, which some builds omit).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.delta import signed_edge_delta
from repro.core.graph import GraphSnapshot, HostGraph
from repro.kernels.block_spmv import ops

try:  # the GNN family needs the dist substrate; PageRank paths do not
    from repro.models.gnn.common import GNNConfig, GraphBatch
    HAVE_GNN = True
except ImportError:  # pragma: no cover - depends on build flavor
    GNNConfig = GraphBatch = None
    HAVE_GNN = False


def effective_batch(hg_prev: HostGraph, deletions: np.ndarray,
                    insertions: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Filter a raw (deletions, insertions) batch down to the edges that
    actually change the graph, mirroring :meth:`HostGraph.apply_batch`
    exactly: dedupe, drop self-loops, deletions of absent edges are no-ops,
    insertions land in (prev − dels) — so an edge deleted and re-inserted
    in one batch nets to zero."""
    n = np.int64(hg_prev.n)

    def uniq(e):
        e = np.asarray(e, np.int64).reshape(-1, 2)
        e = e[e[:, 0] != e[:, 1]]
        k = np.unique(e[:, 0] * n + e[:, 1])
        return np.stack([k // n, k % n], 1), k

    dels, del_keys = uniq(deletions)
    ins, ins_keys = uniq(insertions)
    dels = dels[hg_prev.has_edges(dels)] if len(dels) else dels
    if len(ins):
        present = hg_prev.has_edges(ins)
        redeleted = np.isin(ins_keys, del_keys) if len(del_keys) else \
            np.zeros(len(ins), bool)
        ins = ins[~present | (present & redeleted)]
    return dels, ins


@dataclasses.dataclass
class MatrixAux:
    """Per-block engine operands cached alongside the pull matrix so a
    stream never recomputes them from scratch per ``run_pallas`` call:

    * ``bmat``   — tile-presence adjacency [n_rb, n_cb] (candidate-block
      selection for the OR-pass); monotone under deltas.
    * ``rb_in``  — in-edge count per dst-block (sweep work metric), equal
      to ``GraphSnapshot.block_in_edges()`` of the current graph.
    * ``rb_out`` — out-edge count per src-block (expansion work metric).

    All three update in O(batch) from the signed delta coordinates.
    """
    bmat: np.ndarray     # [n_rb, n_cb] bool
    rb_in: np.ndarray    # [n_rb] i32
    rb_out: np.ndarray   # [n_rb] i32

    @classmethod
    def from_parts(cls, mat: ops.BlockSparse, g: GraphSnapshot
                   ) -> "MatrixAux":
        return cls(bmat=np.asarray(ops.block_adjacency(mat)).copy(),
                   rb_in=np.asarray(g.block_in_edges()).copy(),
                   rb_out=np.asarray(g.block_out_edges()).copy())

    def apply_delta(self, block: int, rows: np.ndarray, cols: np.ndarray,
                    vals: np.ndarray) -> None:
        """O(batch) update from signed pull-layout coordinates (rows = dst,
        cols = src, vals = ±1): block degrees move by the signed counts;
        tile presence ORs in every touched pair.

        Fields are *rebound* to fresh arrays, never mutated in place: on
        CPU, ``jnp.asarray`` may alias a numpy buffer zero-copy (the stream
        runner's device mirrors, ``run_pallas(aux=...)`` operands), and an
        in-place write here would race the transfer and corrupt them."""
        if len(rows) == 0:
            return
        rb = np.asarray(rows, np.int64) // block
        cb = np.asarray(cols, np.int64) // block
        v = np.asarray(vals)
        n_rb = self.rb_in.shape[0]
        self.rb_in = self.rb_in + np.bincount(
            rb, weights=v, minlength=n_rb).astype(self.rb_in.dtype)
        self.rb_out = self.rb_out + np.bincount(
            cb, weights=v, minlength=n_rb).astype(self.rb_out.dtype)
        bmat = self.bmat.copy()
        bmat[rb, cb] = True
        self.bmat = bmat


class IncrementalPullMatrix:
    """Block-sparse pull matrix maintained incrementally across snapshots.

    Usage along a dynamic stream::

        inc = IncrementalPullMatrix.from_snapshot(g0, dtype=np.float64)
        ...
        hg1 = hg0.apply_batch(dels, ins)
        g1 = hg1.snapshot(...)
        mat1 = inc.advance(hg0, g1, dels, ins)   # patches touched tiles only
        res = df_pagerank(g0, g1, batch, r, engine="pallas",
                          pallas_mat=mat1, pallas_aux=inc.aux)

    ``advance`` filters the batch against the previous host graph the same
    way :meth:`HostGraph.apply_batch` does (:func:`effective_batch`), so
    tile values track edge multiplicity exactly; self-loops never change
    (every vertex always has one).  Structure grows monotonically — emptied
    tiles stay as zero blocks — so a delete+reinsert round-trip reproduces
    the original matrix values exactly (the paper's §5.2.3 stability
    property, at build level).

    The per-block engine operands (tile-presence adjacency + block-degree
    vectors, :class:`MatrixAux`) are cached and patched per batch instead
    of being recomputed per ``run_pallas`` call; ``padded=True`` (the
    default) builds the matrix capacity-padded so delta batches keep
    ``tiles.shape`` / ``max_tiles`` stable — the recompile-free streaming
    layout (see :mod:`repro.core.stream`).
    """

    def __init__(self, mat: ops.BlockSparse, aux: Optional[MatrixAux] = None):
        self.mat = mat
        self.aux = aux

    @classmethod
    def from_snapshot(cls, g: GraphSnapshot, dtype=np.float64,
                      padded: bool = True) -> "IncrementalPullMatrix":
        from repro.core.pallas_engine import build_pull_matrix
        mat = build_pull_matrix(g, dtype=dtype, padded=padded)
        return cls(mat, MatrixAux.from_parts(mat, g))

    def advance(self, hg_prev: HostGraph, g_new: Optional[GraphSnapshot],
                deletions: np.ndarray, insertions: np.ndarray, *,
                effective: Optional[Tuple[np.ndarray, np.ndarray]] = None
                ) -> ops.BlockSparse:
        """Patch the matrix (and cached aux) with one edge batch.  ``g_new``
        is only consulted for the grid check and may be None on a stream
        (the grid is fixed; out-of-range coordinates are rejected by
        ``ops.apply_delta`` regardless).  ``effective`` may carry an
        already-filtered (dels, ins) pair so callers that need the
        filtered batch themselves don't run :func:`effective_batch`
        twice."""
        if g_new is not None and g_new.n_pad > self.mat.n_rows:
            raise ValueError("snapshot outgrew the matrix block grid; "
                             "rebuild with from_snapshot")
        dels, ins = (effective if effective is not None
                     else effective_batch(hg_prev, deletions, insertions))
        rows, cols, vals = signed_edge_delta(dels, ins)
        self.mat = ops.apply_delta(self.mat, rows, cols, vals)
        if self.aux is not None:
            self.aux.apply_delta(self.mat.block, rows, cols, vals)
        return self.mat


def edge_update_sources(n_pad: int, deletions: np.ndarray,
                        insertions: np.ndarray) -> jnp.ndarray:
    """Indicator of update source vertices (both endpoints for undirected
    message passing: a changed edge changes BOTH endpoints' aggregations)."""
    ind = np.zeros(n_pad + 1, dtype=bool)
    for batch in (deletions, insertions):
        b = np.asarray(batch, np.int64).reshape(-1, 2)
        ind[np.minimum(b[:, 0], n_pad)] = True
        ind[np.minimum(b[:, 1], n_pad)] = True
    return jnp.asarray(ind[:n_pad])


def out_neighbors_or(g: GraphBatch, flags: jnp.ndarray) -> jnp.ndarray:
    """Nodes receiving at least one message from a flagged node."""
    f = jnp.concatenate([flags, jnp.zeros((1,), flags.dtype)])
    hit = jax.ops.segment_max(
        f[jnp.minimum(g.senders, g.n_pad)].astype(jnp.int32),
        g.receivers, num_segments=g.n_pad + 1)[:g.n_pad]
    return hit > 0


def incremental_gnn_update(
        layer_fns, g: GraphBatch, h0: jnp.ndarray,
        cached_layers, sources: jnp.ndarray, *, tau_f: float
) -> Tuple[jnp.ndarray, list, Dict[str, int]]:
    """Recompute a layered GNN after a graph update, DF-style.

    layer_fns[i](g, h) -> h'  — full-graph layer functions;
    cached_layers[i]          — pre-update activations per layer (i=0 input);
    sources                   — indicator of update-source nodes.

    Per layer: recompute only currently-affected nodes (others keep their
    cached activation), then expand the frontier to the out-neighbors of
    nodes whose activation moved more than τ_f — the DF gate.  Returns the
    new final activations, the refreshed cache, and work counters.
    """
    affected = out_neighbors_or(g, sources) | sources
    new_cache = [h0]
    h = h0
    stats = {"recomputed": 0, "total": 0}
    for i, fn in enumerate(layer_fns):
        full = fn(g, h)                       # masked cost model: a real
        # deployment computes only affected rows; on TPU the win is measured
        # in the affected-row count (stats) while XLA computes dense tiles.
        prev = cached_layers[i + 1]
        h_new = jnp.where(affected[:, None], full, prev)
        moved = affected & (
            jnp.max(jnp.abs(h_new - prev), axis=-1) > tau_f)
        stats["recomputed"] += int(affected.sum())
        stats["total"] += int(g.n_pad)
        affected = affected | out_neighbors_or(g, moved)
        new_cache.append(h_new)
        h = h_new
    return h, new_cache, stats


def full_gnn_layers(mod, params, cfg: GNNConfig):
    """Adapt a model-zoo family into per-layer closures for the incremental
    path (graphsage-style: h' = layer(h))."""
    if cfg.family != "graphsage":
        raise NotImplementedError(
            "incremental path is exercised on graphsage (mean aggregation "
            "is layer-local); other families need their edge state threaded")
    from repro.models.gnn import graphsage as GS
    from repro.models.gnn import common as C

    def make(i):
        def fn(g, h):
            neigh = C.scatter_mean(g, C.gather_src(g, h))
            return GS._layer(params, i, h, neigh)
        return fn

    return [make(i) for i in range(cfg.n_layers)]
