"""Incremental maintenance of dynamic-graph state (beyond-paper).

Two members:

* :class:`IncrementalPullMatrix` — keeps the fused Pallas engine's
  block-sparse pull matrix in sync with a dynamic edge stream by patching
  only the tiles each batch touches (``ops.apply_delta``), instead of the
  O(m) host rebuild per snapshot.  This is the state carrier that makes the
  ``engine="pallas"`` path incremental end-to-end: frontier-proportional
  *compute* per sweep and batch-proportional *build* per snapshot.

* ``incremental_gnn_update`` — DF generalized to GNN vertex programs
  (DESIGN.md §Arch-applicability): after a batch of edge updates only nodes
  whose embeddings can change are re-embedded, with τ_f cutting off the
  receptive-field cone.  Gated on the model zoo being importable (the GNN
  stack needs :mod:`repro.dist`, which some builds omit).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.delta import signed_edge_delta
from repro.core.graph import GraphSnapshot, HostGraph
from repro.kernels.block_spmv import ops

try:  # the GNN family needs the dist substrate; PageRank paths do not
    from repro.models.gnn.common import GNNConfig, GraphBatch
    HAVE_GNN = True
except ImportError:  # pragma: no cover - depends on build flavor
    GNNConfig = GraphBatch = None
    HAVE_GNN = False


class IncrementalPullMatrix:
    """Block-sparse pull matrix maintained incrementally across snapshots.

    Usage along a dynamic stream::

        inc = IncrementalPullMatrix.from_snapshot(g0, dtype=np.float64)
        ...
        hg1 = hg0.apply_batch(dels, ins)
        g1 = hg1.snapshot(...)
        mat1 = inc.advance(hg0, g1, dels, ins)   # patches touched tiles only
        res = df_pagerank(g0, g1, batch, r, engine="pallas",
                          pallas_mat=mat1)

    ``advance`` filters the batch against the previous host graph the same
    way :meth:`HostGraph.apply_batch` does (drop deletions of absent edges,
    insertions of present ones, self-loops), so tile values track edge
    multiplicity exactly; self-loops never change (every vertex always has
    one).  Structure grows monotonically — emptied tiles stay as zero
    blocks — so a delete+reinsert round-trip reproduces the original matrix
    values exactly (the paper's §5.2.3 stability property, at build level).
    """

    def __init__(self, mat: ops.BlockSparse):
        self.mat = mat

    @classmethod
    def from_snapshot(cls, g: GraphSnapshot, dtype=np.float64
                      ) -> "IncrementalPullMatrix":
        from repro.core.pallas_engine import build_pull_matrix
        return cls(build_pull_matrix(g, dtype=dtype))

    def advance(self, hg_prev: HostGraph, g_new: GraphSnapshot,
                deletions: np.ndarray, insertions: np.ndarray
                ) -> ops.BlockSparse:
        if g_new.n_pad > self.mat.n_rows:
            raise ValueError("snapshot outgrew the matrix block grid; "
                             "rebuild with from_snapshot")
        n = np.int64(hg_prev.n)

        def uniq(e):
            e = np.asarray(e, np.int64).reshape(-1, 2)
            e = e[e[:, 0] != e[:, 1]]
            k = np.unique(e[:, 0] * n + e[:, 1])
            return np.stack([k // n, k % n], 1), k

        # mirror HostGraph.apply_batch exactly: dedupe, drop self-loops,
        # deletions of absent edges are no-ops, insertions land in
        # (prev − dels) — so an edge deleted and re-inserted in one batch
        # nets to zero
        dels, del_keys = uniq(deletions)
        ins, ins_keys = uniq(insertions)
        dels = dels[hg_prev.has_edges(dels)] if len(dels) else dels
        if len(ins):
            present = hg_prev.has_edges(ins)
            redeleted = np.isin(ins_keys, del_keys) if len(del_keys) else \
                np.zeros(len(ins), bool)
            ins = ins[~present | (present & redeleted)]
        rows, cols, vals = signed_edge_delta(dels, ins)
        self.mat = ops.apply_delta(self.mat, rows, cols, vals)
        return self.mat


def edge_update_sources(n_pad: int, deletions: np.ndarray,
                        insertions: np.ndarray) -> jnp.ndarray:
    """Indicator of update source vertices (both endpoints for undirected
    message passing: a changed edge changes BOTH endpoints' aggregations)."""
    ind = np.zeros(n_pad + 1, dtype=bool)
    for batch in (deletions, insertions):
        b = np.asarray(batch, np.int64).reshape(-1, 2)
        ind[np.minimum(b[:, 0], n_pad)] = True
        ind[np.minimum(b[:, 1], n_pad)] = True
    return jnp.asarray(ind[:n_pad])


def out_neighbors_or(g: GraphBatch, flags: jnp.ndarray) -> jnp.ndarray:
    """Nodes receiving at least one message from a flagged node."""
    f = jnp.concatenate([flags, jnp.zeros((1,), flags.dtype)])
    hit = jax.ops.segment_max(
        f[jnp.minimum(g.senders, g.n_pad)].astype(jnp.int32),
        g.receivers, num_segments=g.n_pad + 1)[:g.n_pad]
    return hit > 0


def incremental_gnn_update(
        layer_fns, g: GraphBatch, h0: jnp.ndarray,
        cached_layers, sources: jnp.ndarray, *, tau_f: float
) -> Tuple[jnp.ndarray, list, Dict[str, int]]:
    """Recompute a layered GNN after a graph update, DF-style.

    layer_fns[i](g, h) -> h'  — full-graph layer functions;
    cached_layers[i]          — pre-update activations per layer (i=0 input);
    sources                   — indicator of update-source nodes.

    Per layer: recompute only currently-affected nodes (others keep their
    cached activation), then expand the frontier to the out-neighbors of
    nodes whose activation moved more than τ_f — the DF gate.  Returns the
    new final activations, the refreshed cache, and work counters.
    """
    affected = out_neighbors_or(g, sources) | sources
    new_cache = [h0]
    h = h0
    stats = {"recomputed": 0, "total": 0}
    for i, fn in enumerate(layer_fns):
        full = fn(g, h)                       # masked cost model: a real
        # deployment computes only affected rows; on TPU the win is measured
        # in the affected-row count (stats) while XLA computes dense tiles.
        prev = cached_layers[i + 1]
        h_new = jnp.where(affected[:, None], full, prev)
        moved = affected & (
            jnp.max(jnp.abs(h_new - prev), axis=-1) > tau_f)
        stats["recomputed"] += int(affected.sum())
        stats["total"] += int(g.n_pad)
        affected = affected | out_neighbors_or(g, moved)
        new_cache.append(h_new)
        h = h_new
    return h, new_cache, stats


def full_gnn_layers(mod, params, cfg: GNNConfig):
    """Adapt a model-zoo family into per-layer closures for the incremental
    path (graphsage-style: h' = layer(h))."""
    if cfg.family != "graphsage":
        raise NotImplementedError(
            "incremental path is exercised on graphsage (mean aggregation "
            "is layer-local); other families need their edge state threaded")
    from repro.models.gnn import graphsage as GS
    from repro.models.gnn import common as C

    def make(i):
        def fn(g, h):
            neigh = C.scatter_mean(g, C.gather_src(g, h))
            return GS._layer(params, i, h, neigh)
        return fn

    return [make(i) for i in range(cfg.n_layers)]
