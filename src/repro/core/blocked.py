"""Blocked frontier sweep engine — the TPU-native heart of DF_BB / DF_LF.

Vertices are grouped into fixed blocks (the paper's chunks).  Each sweep:
  1. compacts the ids of *active* blocks (``jnp.nonzero(..., size=K)``) — the
     static-shape analogue of the paper's dynamic work pool;
  2. ``lax.scan``s over the compacted slots.  Per slot the block's in-edges are
     pulled in fixed tiles with a traced-bound ``fori_loop`` → work is
     proportional to the block's real edge count, so a small frontier costs a
     small sweep (the DF speedup survives the static-shape world);
  3. LF mode (Gauss–Seidel): ranks are updated **in place**, later slots see
     earlier slots' fresh ranks within the same sweep — the lock-free
     asynchronous semantics.  BB mode (Jacobi): all reads come from the frozen
     sweep-start vector and a barrier (global L∞) follows;
  4. if the rank of a vertex moves more than τ_f, its out-neighbors are
     OR-scattered as affected (frontier expansion, edge-proportional);
  5. per-slot masks simulate delayed / crashed pseudo-threads: a masked slot
     does no work and its block simply stays flagged for a later sweep.

Everything is static-shaped; one jit cache entry per (snapshot family, K),
with K drawn from the fixed ladder :func:`slot_buckets` (recomputed every
sweep, so capacity both grows and shrinks with the frontier while the cache
stays bounded).  α/τ/τ_f are *traced operands*, not static arguments — a
hyperparameter sweep reuses one compiled sweep.

This engine drives its loop from Python and pays a host↔device round-trip
per sweep (active count, convergence flag, per-sweep stats).  It is kept as
the in-sweep Gauss–Seidel reference and fault-model oracle; the production
hot path is the fully fused device-resident driver in
:mod:`repro.core.pallas_engine` (see docs/ENGINES.md).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.graph import GraphSnapshot
from repro.core import faults as flt
from repro.core import frontier as fr


@dataclasses.dataclass
class SweepStats:
    sweeps: int = 0
    iterations: int = 0           # BB barrier iterations (== sweeps for LF)
    blocks_processed: int = 0
    edges_processed: int = 0
    sim_time_ms: float = 0.0
    converged: bool = False
    dnf: bool = False             # BB stalled at barrier due to a crash


def _slot_body(g: GraphSnapshot, *, tile: int, expand: bool, jacobi: bool,
               alpha, tau, tau_f, dtype, edges=None):
    """Returns the scan body processing one compacted block slot.

    ``alpha``/``tau``/``tau_f`` may be traced scalars — they participate
    only in arithmetic, never in shapes.  ``edges`` (optional) is a paged
    edge view — ``(src, dst, osrc, odst, in_lo, in_len, out_lo, out_len)``
    from :class:`repro.core.tiering.EdgePager` — that redirects the
    per-block edge reads into a bounded device slab; ``None`` reads the
    snapshot's full device-resident CSR arrays, bit-identically to before
    the pager existed."""
    B = g.block_size
    T = tile
    n_pad = g.n_pad
    if edges is None:
        e_src, e_dst, e_osrc, e_odst = g.src, g.dst, g.osrc, g.odst
        in_lo = in_len = out_lo = out_len = None
    else:
        e_src, e_dst, e_osrc, e_odst, in_lo, in_len, out_lo, out_len = edges
    iota = jnp.arange(T, dtype=jnp.int32)
    base_rank = ((1.0 - jnp.asarray(alpha, dtype)) / g.n).astype(dtype)
    alpha_c = jnp.asarray(alpha, dtype)
    tau_c = jnp.asarray(tau, dtype)
    tau_f_c = jnp.asarray(tau_f, dtype)

    def body(carry, slot):
        R, R_read, affected, RC, maxdr = carry
        b, do = slot
        real = do & (b >= 0)
        bsafe = jnp.maximum(b, 0)
        base = bsafe * B

        if edges is None:
            lo = g.in_block_ptr[bsafe]
            hi = g.in_block_ptr[bsafe + 1]
        else:
            lo = in_lo[bsafe]
            hi = lo + in_len[bsafe]
        n_tiles = jnp.where(real, (hi - lo + T - 1) // T, 0)

        read = R_read if jacobi else R
        inv_deg = carry_inv_deg  # closed over below

        def tile_step(t, acc):
            start = lo + t * T
            s = lax.dynamic_slice(e_src, (start,), (T,))
            d = lax.dynamic_slice(e_dst, (start,), (T,))
            ev = (start + iota) < hi
            c = jnp.where(ev, read[jnp.minimum(s, n_pad - 1)] * inv_deg[s], 0)
            lidx = jnp.where(ev, d - base, B).astype(jnp.int32)
            return acc + jax.ops.segment_sum(c, lidx, num_segments=B + 1)[:B]

        acc = lax.fori_loop(0, n_tiles, tile_step, jnp.zeros((B,), dtype))
        r_new = base_rank + alpha_c * acc

        old = lax.dynamic_slice(R, (base,), (B,))
        aff_b = lax.dynamic_slice(affected, (base,), (B,))
        vv_b = lax.dynamic_slice(g.vertex_valid, (base,), (B,))
        upd = aff_b & vv_b & real
        r_fin = jnp.where(upd, r_new, old)
        dr = jnp.where(upd, jnp.abs(r_fin - old), 0)
        R = lax.dynamic_update_slice(R, r_fin, (base,))

        rc_b = lax.dynamic_slice(RC, (base,), (B,))
        rc_new = jnp.where(upd, dr > tau_c, rc_b)
        RC = lax.dynamic_update_slice(RC, rc_new, (base,))
        maxdr = jnp.maximum(maxdr, jnp.max(dr))

        edges_in = jnp.where(real, hi - lo, 0)
        edges_out = jnp.int32(0)

        if expand:
            changed = upd & (dr > tau_f_c)
            if edges is None:
                olo = g.out_block_ptr[bsafe]
                ohi = g.out_block_ptr[bsafe + 1]
            else:
                olo = out_lo[bsafe]
                ohi = olo + out_len[bsafe]
            n_ot = jnp.where(real & changed.any(), (ohi - olo + T - 1) // T, 0)

            def otile(t, st):
                affected, RC = st
                start = olo + t * T
                u = lax.dynamic_slice(e_osrc, (start,), (T,))
                w = lax.dynamic_slice(e_odst, (start,), (T,))
                ev = (start + iota) < ohi
                lsrc = jnp.clip(u - base, 0, B - 1)
                flag = ev & changed[lsrc]
                tgt = jnp.where(flag, w, n_pad)
                affected = affected.at[tgt].set(True)
                RC = RC.at[tgt].set(True)
                return affected, RC

            affected, RC = lax.fori_loop(0, n_ot, otile, (affected, RC))
            edges_out = jnp.where(real & changed.any(), ohi - olo, 0)

        return ((R, R_read, affected, RC, maxdr),
                (edges_in + edges_out,))

    # degrees are fixed for the snapshot; precompute reciprocal with phantom 0
    deg = jnp.maximum(g.out_deg, 1).astype(dtype)
    inv = jnp.where(g.vertex_valid, 1.0 / deg, 0).astype(dtype)
    carry_inv_deg = jnp.concatenate([inv, jnp.zeros((1,), dtype)])
    return body


@partial(jax.jit, static_argnames=("tile", "expand", "jacobi", "dtype_name"))
def sweep(g: GraphSnapshot, R, affected, RC, slot_ids, slot_mask,
          R_read, alpha, tau, tau_f, edges=None, *, tile: int, expand: bool,
          jacobi: bool, dtype_name: str):
    """One compacted sweep over up to K = len(slot_ids) active blocks.

    α/τ/τ_f are traced operands: changing them reuses the jit cache entry
    (one compilation per (snapshot family, K, structure), not per
    hyperparameter point — a τ sweep costs one compile).  ``edges``
    (optional) is an :class:`repro.core.tiering.EdgePager` view: the sweep
    then reads per-block edge slices from the pager's bounded slab (stable
    shapes — one extra cache entry per K, not per staging)."""
    dtype = jnp.dtype(dtype_name)
    body = _slot_body(g, tile=tile, expand=expand, jacobi=jacobi, alpha=alpha,
                      tau=tau, tau_f=tau_f, dtype=dtype, edges=edges)
    carry = (R, R_read, affected, RC, jnp.zeros((), dtype))
    (R, _, affected, RC, maxdr), (edges,) = lax.scan(
        body, carry, (slot_ids, slot_mask))
    return R, affected, RC, maxdr, edges


SLOT_BUCKET_BASE = 16
SLOT_BUCKET_GROWTH = 4


def slot_buckets(n_blocks: int) -> Tuple[int, ...]:
    """The full ladder of slot capacities ``run_blocked`` may ever use for a
    graph with ``n_blocks`` blocks — this bounds the jit cache: at most
    ``len(slot_buckets(n_blocks))`` sweep compilations per (snapshot family,
    dtype, mode), i.e. O(log n_blocks)."""
    out = []
    K = SLOT_BUCKET_BASE
    while K < n_blocks:
        out.append(K)
        K *= SLOT_BUCKET_GROWTH
    out.append(n_blocks)
    return tuple(out)


def slot_capacity(n_act: int, n_blocks: int) -> int:
    """Smallest ladder bucket ≥ n_act (clamped to n_blocks).  Recomputed
    from the ladder base every sweep, so capacity *shrinks* as the frontier
    decays — a small late-phase frontier costs a small sweep — and only the
    ladder values ever reach the jit cache."""
    for K in slot_buckets(n_blocks):
        if K >= n_act:
            return K
    return n_blocks


@partial(jax.jit, static_argnames=("n_blocks", "block_size"))
def active_blocks(flags: jnp.ndarray, *, n_blocks: int, block_size: int):
    """Compact active block ids; returns (ids [n_blocks] w/ -1 fill, count)."""
    act = fr.block_any(flags, n_blocks, block_size)
    return fr.compact_block_ids(act, n_blocks), act.sum()


def run_blocked(g: GraphSnapshot, R0: jnp.ndarray, affected0: jnp.ndarray,
                *, mode: str = "lf", expand: bool = True,
                alpha: float = 0.85, tau: float = 1e-10,
                tau_f: Optional[float] = None, max_iterations: int = 500,
                tile: int = 512, faults: Optional[flt.FaultPlan] = None,
                active_policy: str = "affected", pager=None,
                ) -> Tuple[jnp.ndarray, SweepStats]:
    """Driver loop: compaction → fault masking → sweep → convergence check.

    mode="lf": block-asynchronous Gauss–Seidel, per-vertex RC termination.
    mode="bb": Jacobi with a global L∞ barrier each iteration.

    active_policy selects which blocks a sweep processes:
      "affected" — every block containing an affected vertex (paper Alg. 2
                   line 19 verbatim: converged-but-affected vertices are
                   still recomputed each iteration);
      "rc"       — only blocks containing a NOT-yet-converged vertex (the
                   paper's own "per-chunk converged flag" suggestion,
                   §4.3); any change > τ_f re-marks downstream RC flags, so
                   the τ_f error bound is unchanged.  Beyond-paper
                   optimization measured in §Perf.

    pager (optional, a :class:`repro.core.tiering.EdgePager` over ``g``)
    keeps the snapshot's edge arrays on the host and stages only each
    sweep's active blocks into a bounded device slab — the blocked
    oracle's analogue of the tiered tile pool.  The oracle already syncs
    per sweep, so staging rides the existing round-trip; results are
    identical to the unpaged run (same slices, different addresses).
    """
    if mode not in ("lf", "bb"):
        raise ValueError(mode)
    if active_policy not in ("affected", "rc"):
        raise ValueError(active_policy)
    jacobi = mode == "bb"
    if tau_f is None:
        tau_f = tau / 1000.0 if expand else float("inf")
    if not expand:
        tau_f = float("inf")
    plan = faults or flt.NO_FAULTS
    dtype = R0.dtype
    dtype_name = str(dtype)

    n_pad = g.n_pad
    R = jnp.where(g.vertex_valid, R0[:n_pad], 0).astype(dtype)
    affected = jnp.concatenate(
        [affected0[:n_pad] & g.vertex_valid, jnp.zeros((1,), bool)])
    RC = affected.copy()
    stats = SweepStats()

    for it in range(max_iterations):
        act_flags = (affected if active_policy == "affected" else RC)
        ids_full, n_act = active_blocks(act_flags[:n_pad],
                                        n_blocks=g.n_blocks,
                                        block_size=g.block_size)
        n_act = int(n_act)
        if n_act == 0:
            stats.converged = True
            break
        # capacity-K compaction: the sweep scans K slots, K the smallest
        # ladder bucket ≥ |active| (see slot_buckets: bounded jit cache,
        # capacity shrinks with the frontier — the static-shape work pool)
        K = slot_capacity(n_act, g.n_blocks)
        ids = ids_full[:K]
        # paged edges: stage this sweep's active blocks into the slab (the
        # ids are already on host from the n_act sync — no extra round-trip)
        edges = (pager.ensure(np.asarray(ids_full)[:n_act])
                 if pager is not None else None)

        # dynamic scheduling (paper §3.3.2): compacted slots are drawn from a
        # global pool by the threads *participating* this sweep — a delayed or
        # crashed thread's work is simply picked up by the survivors (at the
        # cost of simulated time), never starved.
        if jacobi:
            # delayed threads still reach the barrier; crashes stall it
            if plan.any_crashed(it):
                stats.dnf = True
                break
            workers = np.arange(plan.n_threads)
        else:
            part = plan.participating(it)
            if not part.any():          # everyone asleep this sweep
                stats.sweeps += 1
                stats.sim_time_ms += plan.delay_ms
                continue
            workers = np.nonzero(part)[0]
        assign = workers[np.arange(K) % len(workers)]
        slot_mask_np = np.arange(K) < n_act           # compacted real slots
        slot_mask = jnp.asarray(slot_mask_np)

        # functional freeze: in Jacobi mode the body reads the sweep-start R
        R, affected, RC, maxdr, edge_ct = sweep(
            g, R, affected, RC, ids, slot_mask, R,
            jnp.asarray(alpha, dtype), jnp.asarray(tau, dtype),
            jnp.asarray(tau_f, dtype), edges, tile=tile, expand=expand,
            jacobi=jacobi, dtype_name=dtype_name)

        edges_np = np.asarray(edge_ct)
        mask_np = np.asarray(slot_mask)
        thread_edges = np.bincount(assign[mask_np],
                                   weights=edges_np[mask_np],
                                   minlength=plan.n_threads)
        thread_blocks = np.bincount(assign[mask_np],
                                    minlength=plan.n_threads)
        stats.sim_time_ms += plan.sweep_time_ms(
            it, thread_edges, thread_blocks, barrier=jacobi)
        stats.sweeps += 1
        stats.iterations += 1
        stats.blocks_processed += int(mask_np.sum())
        stats.edges_processed += int(edges_np[mask_np].sum())

        if jacobi:
            if float(maxdr) <= tau:
                stats.converged = True
                break
        else:
            if not bool(RC[:n_pad].any()):
                stats.converged = True
                break

    return R[:n_pad], stats


# ---------------------------------------------------------------------------
# repro.api engine adapter (Engine protocol; discovered lazily by
# repro.api.registry so this module never imports the api package)
# ---------------------------------------------------------------------------

class BlockedEngine:
    """Registry adapter for the blocked frontier sweep engine."""

    name = "blocked"
    fault_domains = ("thread", "process")

    def run(self, g, R0, affected0, *, mode, expand, alpha, tau, tau_f,
            max_iterations, faults, tile, active_policy,
            mat=None, aux=None, backend=None, interpret=None, shards=None):
        from repro.api.registry import (reject_shard_spec,
                                        reject_tile_operands)
        reject_tile_operands(self.name, mat, aux, backend)
        reject_shard_spec(self.name, shards)
        R, stats = run_blocked(
            g, R0, affected0, mode=mode, expand=expand, alpha=alpha,
            tau=tau, tau_f=tau_f, max_iterations=max_iterations, tile=tile,
            faults=faults, active_policy=active_policy)
        return jax.block_until_ready(R), stats


def as_engine() -> BlockedEngine:
    return BlockedEngine()
