"""Graph substrate: host-side dynamic graph store + device snapshots.

The paper (§3.4) assumes batch updates interleave with computation against a
read-only *snapshot* of the graph.  We mirror that: ``HostGraph`` is the mutable
(functionally-updated) host-side store built on numpy; ``GraphSnapshot`` is the
immutable, padded, device-resident view that every JAX algorithm consumes.

Layout decisions (TPU-native, see DESIGN.md §2):
  * in-edges stored as flat (src, dst) arrays sorted by dst  → pull-mode SpMV is
    ``segment_sum(contrib[src], dst)``;
  * out-edges stored sorted by src                            → frontier expansion
    is an OR-scatter over out-edge tiles;
  * vertices grouped into fixed-size blocks (the paper's "chunks"); per-block
    edge ranges (``in_block_ptr`` / ``out_block_ptr``) drive the blocked
    frontier engine in :mod:`repro.core.blocked`;
  * all arrays padded to static capacities with sentinel vertex id ``n`` so a
    snapshot family shares one jit cache across a dynamic stream.

Self-loops are added to every vertex (paper §5.1.3) which removes dead ends and
the global teleport correction.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


#: every device index in a snapshot (vertex ids incl. the phantom ``n``,
#: edge offsets, block ptrs) is int32 — the index-width diet that halves
#: slot-table and CSR bytes.  Builds beyond these bounds must fail loudly
#: *before* any cast can wrap.
I32_MAX = np.iinfo(np.int32).max


def _check_i32(value: int, what: str) -> None:
    if value > I32_MAX:
        raise OverflowError(
            f"{what} = {value} exceeds int32 ({I32_MAX}); the device "
            "snapshot uses 32-bit indices — shard the graph or widen the "
            "index dtype")


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class GraphSnapshot:
    """Immutable device view of one time step of a dynamic graph.

    Padded edges carry ``src == dst == n`` (the phantom vertex); rank vectors
    are padded with one trailing zero so gathers through the phantom are 0.
    """

    n: int                    # number of real vertices
    m: int                    # number of real edges (incl. self-loops)
    block_size: int           # vertices per block ("chunk")
    n_blocks: int
    # -- in-edge view (sorted by dst) --------------------------------------
    src: jnp.ndarray          # [m_pad] i32
    dst: jnp.ndarray          # [m_pad] i32
    in_block_ptr: jnp.ndarray  # [n_blocks+1] i32  edge range per dst-block
    # -- out-edge view (sorted by src) -------------------------------------
    osrc: jnp.ndarray         # [m_pad] i32
    odst: jnp.ndarray         # [m_pad] i32
    out_block_ptr: jnp.ndarray  # [n_blocks+1] i32 edge range per src-block
    # -- per-vertex --------------------------------------------------------
    out_deg: jnp.ndarray      # [n_pad] i32 (>=1 thanks to self-loops; 0 on pad)
    vertex_valid: jnp.ndarray  # [n_pad] bool

    @property
    def n_pad(self) -> int:
        return self.n_blocks * self.block_size

    @property
    def m_pad(self) -> int:
        return int(self.src.shape[0])

    def in_edges_host(self) -> Tuple[np.ndarray, np.ndarray]:
        """Host copies of the real (src, dst) in-edge arrays (self-loops
        included) — the input to the block-sparse pull-matrix builder."""
        return (np.asarray(self.src)[:self.m].astype(np.int64),
                np.asarray(self.dst)[:self.m].astype(np.int64))

    def block_in_edges(self) -> jnp.ndarray:
        """[n_blocks] i32: in-edge count per dst-block (sweep work metric)."""
        return self.in_block_ptr[1:] - self.in_block_ptr[:-1]

    def block_out_edges(self) -> jnp.ndarray:
        """[n_blocks] i32: out-edge count per src-block (expansion metric)."""
        return self.out_block_ptr[1:] - self.out_block_ptr[:-1]

    def tree_flatten(self):  # pragma: no cover - registered below
        children = (self.src, self.dst, self.in_block_ptr, self.osrc,
                    self.odst, self.out_block_ptr, self.out_deg,
                    self.vertex_valid)
        aux = (self.n, self.m, self.block_size, self.n_blocks)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):  # pragma: no cover
        n, m, block_size, n_blocks = aux
        (src, dst, ibp, osrc, odst, obp, out_deg, vv) = children
        return cls(n=n, m=m, block_size=block_size, n_blocks=n_blocks,
                   src=src, dst=dst, in_block_ptr=ibp, osrc=osrc, odst=odst,
                   out_block_ptr=obp, out_deg=out_deg, vertex_valid=vv)


jax.tree_util.register_pytree_node(
    GraphSnapshot, GraphSnapshot.tree_flatten, GraphSnapshot.tree_unflatten)


class HostGraph:
    """Host-side dynamic directed graph with batch update support.

    Stores the edge set (without self-loops) as a sorted, de-duplicated
    ``(src, dst)`` uint64-keyed numpy array.  ``apply_batch`` returns a new
    ``HostGraph`` — updates are functional, matching snapshot semantics.
    """

    def __init__(self, n: int, edges: np.ndarray, *, _sorted: bool = False):
        self.n = int(n)
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        # drop self-loops from the *stored* edge set (re-added per snapshot)
        edges = edges[edges[:, 0] != edges[:, 1]]
        keys = edges[:, 0] * np.int64(self.n) + edges[:, 1]
        if not _sorted:
            keys = np.unique(keys)
        self._keys = keys  # sorted unique uint keys

    # -- basic accessors ----------------------------------------------------
    @property
    def m(self) -> int:
        """Edge count *excluding* self-loops."""
        return int(self._keys.shape[0])

    @property
    def edges(self) -> np.ndarray:
        src = self._keys // self.n
        dst = self._keys % self.n
        return np.stack([src, dst], axis=1)

    def has_edges(self, edges: np.ndarray) -> np.ndarray:
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        keys = edges[:, 0] * np.int64(self.n) + edges[:, 1]
        idx = np.searchsorted(self._keys, keys)
        idx = np.clip(idx, 0, max(self.m - 1, 0))
        if self.m == 0:
            return np.zeros(len(keys), dtype=bool)
        return self._keys[idx] == keys

    # -- dynamic updates ----------------------------------------------------
    def apply_batch(self, deletions: np.ndarray, insertions: np.ndarray
                    ) -> "HostGraph":
        dels = np.asarray(deletions, dtype=np.int64).reshape(-1, 2)
        ins = np.asarray(insertions, dtype=np.int64).reshape(-1, 2)
        ins = ins[ins[:, 0] != ins[:, 1]]
        del_keys = dels[:, 0] * np.int64(self.n) + dels[:, 1]
        ins_keys = ins[:, 0] * np.int64(self.n) + ins[:, 1]
        keys = self._keys
        if len(del_keys):
            keep = np.isin(keys, del_keys, invert=True,
                           assume_unique=False)
            keys = keys[keep]
        if len(ins_keys):
            keys = np.unique(np.concatenate([keys, ins_keys]))
        g = HostGraph.__new__(HostGraph)
        g.n = self.n
        g._keys = keys
        return g

    # -- snapshotting ---------------------------------------------------------
    def snapshot(self, *, block_size: int = 256,
                 edge_capacity: Optional[int] = None,
                 dtype=jnp.int32) -> GraphSnapshot:
        """Build the padded device snapshot (self-loops added here).

        Index-width diet: below 2^31 edges every transient (src/dst
        staging, sort outputs, pads) is built int32 directly instead of
        int64-then-cast — at 100M edges that halves the build's peak host
        footprint.  The guards fire *before* any allocation or cast, so an
        over-wide graph raises instead of silently wrapping indices."""
        n = self.n
        n_blocks = max(1, _round_up(n, block_size) // block_size)
        n_pad = n_blocks * block_size
        # phantom vertex id == n must itself fit the index dtype
        _check_i32(n_pad, "padded vertex count")
        m_est = self.m + n
        m_pad_est = edge_capacity if edge_capacity is not None else (
            _round_up(max(m_est, 1), 1024) + 1024)
        _check_i32(m_pad_est, "padded edge capacity")

        # decode straight from the int64 keys to int32 columns — never
        # materializing the [m, 2] int64 edge matrix the ``edges`` property
        # would build
        k = self._keys
        # self-loops for every vertex (paper §5.1.3: removes dead ends)
        loops = np.arange(n, dtype=np.int32)
        src = np.concatenate([(k // n).astype(np.int32), loops])
        dst = np.concatenate([(k % n).astype(np.int32), loops])
        m = src.shape[0]
        # +1024 tail guard: tile reads of up to 1024 edges may overshoot the
        # real edge range; the guard keeps dynamic_slice from clamping the
        # start (which would desynchronize data and validity mask).
        m_pad = edge_capacity if edge_capacity is not None else (
            _round_up(max(m, 1), 1024) + 1024)
        if m_pad < m + 1024:
            raise ValueError(
                f"edge_capacity {m_pad} < edge count {m} + 1024 tail guard")
        _check_i32(m_pad, "padded edge capacity")

        out_deg = np.bincount(src, minlength=n_pad).astype(np.int32)

        def _sorted_padded(key_arr, a, b):
            order = np.argsort(key_arr, kind="stable")
            a, b = a[order], b[order]
            pad = np.full(m_pad - m, n, dtype=np.int32)
            return (np.concatenate([a, pad]),
                    np.concatenate([b, pad]))

        s_dst, s_src_by_dst = _sorted_padded(dst, dst, src)
        # in-edges sorted by dst
        in_dst, in_src = s_dst, s_src_by_dst
        o_src, o_dst = _sorted_padded(src, src, dst)

        def _block_ptr(sorted_vertex_ids: np.ndarray) -> np.ndarray:
            # edge range [ptr[b], ptr[b+1]) for vertices in block b
            bounds = np.arange(n_blocks + 1, dtype=np.int64) * block_size
            return np.searchsorted(
                sorted_vertex_ids[:m], bounds, side="left").astype(np.int32)

        in_bp = _block_ptr(in_dst)
        out_bp = _block_ptr(o_src)

        vv = np.zeros(n_pad, dtype=bool)
        vv[:n] = True

        dev = jnp.asarray
        return GraphSnapshot(
            n=n, m=m, block_size=block_size, n_blocks=n_blocks,
            src=dev(in_src), dst=dev(in_dst), in_block_ptr=dev(in_bp),
            osrc=dev(o_src), odst=dev(o_dst), out_block_ptr=dev(out_bp),
            out_deg=dev(out_deg), vertex_valid=dev(vv))


# ---------------------------------------------------------------------------
# JAX-side helpers shared by the engines
# ---------------------------------------------------------------------------

def contributions(g: GraphSnapshot, ranks: jnp.ndarray) -> jnp.ndarray:
    """``R[u] / outdeg(u)`` padded with a trailing 0 for the phantom vertex."""
    deg = jnp.maximum(g.out_deg, 1).astype(ranks.dtype)
    c = jnp.where(g.vertex_valid, ranks[:g.n_pad] / deg, 0)
    return jnp.concatenate([c, jnp.zeros((1,), dtype=ranks.dtype)])


def pull_all(g: GraphSnapshot, ranks: jnp.ndarray, *, alpha: float,
             personalization: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Dense pull step over every vertex: one full SpMV via segment_sum.

    ``personalization`` (a restart distribution [n_pad], summing to 1 over
    valid vertices) replaces the uniform ``1/n`` teleport — the step then
    iterates toward *personalized* PageRank for that restart."""
    c = contributions(g, ranks)
    pulled = jax.ops.segment_sum(c[g.src], g.dst, num_segments=g.n_pad + 1,
                                 indices_are_sorted=True)[:g.n_pad]
    one_m_a = jnp.asarray(1.0 - alpha, dtype=ranks.dtype)
    if personalization is None:
        base = one_m_a / jnp.asarray(g.n, ranks.dtype)
    else:
        base = one_m_a * jnp.asarray(personalization,
                                     ranks.dtype)[:g.n_pad]
    r = base + jnp.asarray(alpha, ranks.dtype) * pulled
    return jnp.where(g.vertex_valid, r, 0)


def out_neighbor_or(g: GraphSnapshot, flags: jnp.ndarray) -> jnp.ndarray:
    """OR-semiring SpMV on the transposed adjacency: returns the indicator of
    vertices having at least one in-neighbor with ``flags`` set (i.e. the
    out-neighborhood of the flagged set).  Used for frontier expansion and
    the initial affected marking."""
    f = jnp.concatenate([flags.astype(jnp.int32),
                         jnp.zeros((1,), jnp.int32)])
    hit = jax.ops.segment_max(f[g.osrc], g.odst, num_segments=g.n_pad + 1,
                              indices_are_sorted=False)[:g.n_pad]
    return (hit > 0) & g.vertex_valid


def initial_ranks(g: GraphSnapshot, dtype=jnp.float64) -> jnp.ndarray:
    r = jnp.full((g.n_pad,), 1.0 / g.n, dtype=dtype)
    return jnp.where(g.vertex_valid, r, 0)


def pad_ranks(g: GraphSnapshot, ranks: jnp.ndarray) -> jnp.ndarray:
    """Pad/crop a rank vector from another snapshot family onto this one."""
    r = jnp.zeros((g.n_pad,), dtype=ranks.dtype)
    k = min(int(ranks.shape[0]), g.n_pad)
    return r.at[:k].set(ranks[:k])
