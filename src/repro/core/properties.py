"""System invariants, written as checkable predicates.

These back the hypothesis property tests (tests/test_properties.py) and
double as runtime assertions in the examples.  Each mirrors a claim the
paper relies on:

  I1  rank conservation  — Σ R[v] ≈ 1 at a PageRank fixed point (self-loop
      construction removes dead-end leakage);
  I2  idempotent marking — marking affected vertices twice == once (the
      property that makes the helping mechanism race-free, §4.4);
  I3  monotone frontier  — within one batch's computation, the affected set
      only grows;
  I4  fault-schedule soundness — crashed threads never participate again;
      delayed threads return; at least one thread participates in some sweep
      (lock-freedom's "some thread makes progress");
  I5  stability          — delete(B) then insert(B) returns the original
      edge set exactly (HostGraph functional-update correctness).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.graph import GraphSnapshot, HostGraph
from repro.core import frontier as fr


def rank_conservation_error(g: GraphSnapshot, ranks: jnp.ndarray) -> float:
    """|Σ ranks − 1|; near 0 at a fixed point of the self-loop system."""
    return float(jnp.abs(jnp.sum(ranks[:g.n_pad]) - 1.0))


def marking_idempotent(g_prev: GraphSnapshot, g_cur: GraphSnapshot,
                       batch: jnp.ndarray) -> bool:
    once = fr.initial_affected(g_prev, g_cur, batch)
    twice = once | fr.initial_affected(g_prev, g_cur, batch)
    return bool(jnp.array_equal(once, twice))


def frontier_monotone(before: jnp.ndarray, after: jnp.ndarray) -> bool:
    return bool(jnp.all(jnp.logical_or(~before, after)))


def fault_schedule_sound(plan, horizon: int = 64) -> bool:
    crashed_stay_crashed = all(
        not np.any(plan.alive(t) & ~plan.alive(t - 1))
        for t in range(1, horizon))
    someone_progresses = any(plan.participating(t).any()
                             for t in range(horizon))
    return crashed_stay_crashed and someone_progresses


def delete_insert_roundtrip(hg: HostGraph, batch: np.ndarray) -> bool:
    """I5: removing then re-adding a batch restores the exact edge set."""
    present = hg.has_edges(batch)
    batch = batch[present]
    g2 = hg.apply_batch(batch, np.zeros((0, 2), np.int64))
    g3 = g2.apply_batch(np.zeros((0, 2), np.int64), batch)
    return bool(np.array_equal(hg.edges, g3.edges))


def ranks_match_reference(ranks: jnp.ndarray, reference: jnp.ndarray,
                          *, tol: float) -> bool:
    """Paper §5.1.5: L∞ distance to the reference must stay below tol."""
    k = min(ranks.shape[0], reference.shape[0])
    return float(jnp.max(jnp.abs(ranks[:k] - reference[:k]))) <= tol
