"""Streaming DF_LF runtime — now a thin layer over `repro.api`.

The recompile-free streaming machinery introduced here in PR 2 (capacity-
padded incremental pull matrix, device-resident operand mirrors patched by
one O(batch) scatter, tile-matrix frontier seeding, the snapshot-free fused
driver re-entry) moved into :class:`repro.api.session.PageRankSession` —
the session *is* the stream state now, and also serves queries, forks
what-if branches and reports latency.  This module keeps the historical
stream-driving surface:

* :class:`StreamRunner` — wraps one stream-mode session; ``step`` delegates
  to :meth:`PageRankSession.update` (every PR-2 guarantee — zero
  post-warmup driver retraces, frontier-proportional per-batch work —
  is preserved through the session and asserted in ``tests/test_stream.py``
  and ``tests/test_api_surface.py``);
* :func:`run_stream` — drive a whole batch stream and aggregate p50/p95
  latency + post-warmup retrace counts;
* re-exports of the jitted hot-path pieces (``_seed_affected``,
  ``_apply_operand_delta``) and :class:`StreamBatchResult` for existing
  importers.

New code should use :class:`repro.api.PageRankSession` directly (for one
stream) or :class:`repro.api.PageRankService` (for many).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.graph import HostGraph

__all__ = [
    "StreamRunner", "StreamBatchResult", "StreamReport", "run_stream",
    "_seed_affected", "_apply_operand_delta", "_driver_cache_size",
]

# session members re-exported here for existing importers; resolved lazily
# (PEP 562) because repro.api.session imports repro.core — an eager import
# would cycle through repro.core.__init__ during api-first imports
_SESSION_EXPORTS = ("StreamBatchResult", "_seed_affected",
                    "_apply_operand_delta", "_driver_cache_size")


def __getattr__(name: str):
    if name in _SESSION_EXPORTS:
        from repro.api import session as _session
        return getattr(_session, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class StreamReport:
    """Aggregate latency/compile/convergence statistics over a stream."""
    results: List[StreamBatchResult]
    wall_times_s: List[float]
    p50_s: float
    p95_s: float
    retraces_post_warmup: int     # driver cache growth after batch 1
    batches_converged: int = 0    # batches that met tau within the cap
    sweep_cap_hits: int = 0       # batches stopped by max_iterations instead

    @property
    def final_ranks(self) -> jnp.ndarray:
        return self.results[-1].ranks

    @property
    def all_converged(self) -> bool:
        return self.sweep_cap_hits == 0


class StreamRunner:
    """Drives DF_LF PageRank along a dynamic edge stream with a
    recompile-free, frontier-proportional per-batch hot path.

    Usage::

        runner = StreamRunner(hg0, block_size=64)
        for dels, ins in batches:
            res = runner.step(dels, ins)     # converged ranks + latency
        # or: report = run_stream(hg0, batches)

    This is a compatibility wrapper: it opens one stream-mode
    :class:`repro.api.PageRankSession` (``self.session``) and forwards to
    it.  The vertex set (and hence the block grid) is fixed for the
    lifetime of the runner; growing past it requires a new runner.  Rank
    state warm starts each batch from the previous converged vector (the
    dynamic PageRank setting).  ``r0=None`` runs one static solve on the
    initial graph (also serving as the engine warmup).
    """

    def __init__(self, hg0: HostGraph, *, block_size: int = 64,
                 dtype=np.float64, r0: Optional[jnp.ndarray] = None,
                 mode: str = "lf", active_policy: str = "affected",
                 alpha: float = 0.85, tau: float = 1e-10,
                 tau_f: Optional[float] = None, max_iterations: int = 500,
                 interpret: Optional[bool] = None,
                 backend: Optional[str] = None,
                 durability: str = "none",
                 store_dir: Optional[str] = None,
                 checkpoint_interval: int = 16,
                 driver: str = "pull"):
        from repro.api import EngineConfig, PageRankSession
        cfg = EngineConfig(engine="pallas", mode=mode,
                           active_policy=active_policy, alpha=alpha,
                           tau=tau, tau_f=tau_f,
                           max_iterations=max_iterations, backend=backend,
                           block_size=block_size, dtype=dtype,
                           durability=durability,
                           checkpoint_interval=checkpoint_interval,
                           driver=driver)
        self.session = PageRankSession.from_graph(
            hg0, config=cfg, r0=r0, interpret=interpret,
            store_dir=store_dir)

    def warmup(self) -> None:
        """Trace the full per-batch pipeline at the stream's operand shapes
        without perturbing graph or rank state (see
        :meth:`PageRankSession.warmup`)."""
        self.session.warmup()

    def step(self, deletions: np.ndarray, insertions: np.ndarray
             ) -> StreamBatchResult:
        """Apply one edge batch and reconverge: delta scatter → frontier
        seed → fused convergence loop, all device-side after the O(batch)
        host bookkeeping.  Returns the converged ranks and latency stats."""
        return self.session.update(deletions, insertions)

    # -- state passthroughs (the session owns the stream state) -------------
    @property
    def hg(self) -> HostGraph:
        return self.session.hg

    @property
    def R(self):
        return self.session.R

    @property
    def inc(self):
        return self.session.inc

    @property
    def valid(self):
        return self.session.valid

    @property
    def n(self) -> int:
        return self.session.n

    @property
    def n_pad(self) -> int:
        return self.session.n_pad

    @property
    def block_size(self) -> int:
        return self.session.block_size

    @property
    def n_rb(self) -> int:
        return self.session.n_rb

    @property
    def mode(self) -> str:
        return self.session.config.mode

    @property
    def active_policy(self) -> str:
        return self.session.config.active_policy

    @property
    def max_iterations(self) -> int:
        return self.session.config.max_iterations

    @property
    def interpret(self) -> bool:
        return self.session.interpret

    @property
    def backend(self) -> str:
        return self.session.backend

    @property
    def _out_deg(self):
        return self.session._out_deg

    @property
    def _rb_in(self):
        return self.session._rb_in

    @property
    def _rb_out(self):
        return self.session._rb_out

    @property
    def _bmat(self):
        return self.session._bmat


def run_stream(hg0: HostGraph,
               batches: Iterable[Tuple[np.ndarray, np.ndarray]],
               warmup: bool = True, **runner_kwargs) -> StreamReport:
    """Run a whole stream of (deletions, insertions) batches and aggregate
    per-batch latency (p50/p95) and post-warmup retrace counts.  Keyword
    arguments are forwarded to :class:`StreamRunner`.

    ``warmup=True`` runs :meth:`StreamRunner.warmup` first (not recorded):
    it traces the delta/seed/driver pipeline at the stream's operand shapes
    without perturbing the graph, so recorded latencies are steady-state
    (up to batches reaching a not-yet-seen size bucket) and the retrace
    count covers **every** recorded batch, including the first."""
    runner = StreamRunner(hg0, **runner_kwargs)
    if warmup:
        runner.warmup()
    # measure the cache of THIS stream's driver (push sessions count the
    # push driver's jit cache, pull sessions the pull driver's)
    base = runner.session._drv_cache_size() if warmup else -1
    results: List[StreamBatchResult] = []
    for dels, ins in batches:
        results.append(runner.step(dels, ins))
    if not results:
        raise ValueError("empty stream")
    walls = [r.wall_time_s for r in results]
    caches = [r.driver_cache_size for r in results]
    # with a warmup, any driver compile during a recorded batch counts as a
    # retrace; without one, the first batch's (expected) trace is excluded
    if not caches or caches[-1] < 0:
        retraces = -1
    elif warmup and base >= 0:
        retraces = caches[-1] - base
    else:
        retraces = caches[-1] - caches[0]
    converged = sum(1 for r in results if r.stats.converged)
    return StreamReport(
        results=results, wall_times_s=walls,
        p50_s=float(np.percentile(walls, 50)),
        p95_s=float(np.percentile(walls, 95)),
        retraces_post_warmup=retraces,
        batches_converged=converged,
        sweep_cap_hits=len(results) - converged)
