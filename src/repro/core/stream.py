"""Streaming DF_LF runtime — recompile-free dynamic streams.

The paper's setting is a *stream*: batches of edge updates interleave with
PageRank recomputation, and the promise of Dynamic Frontier is that the
cost of a step tracks the batch, not the graph.  Two fixed costs defeat
that promise if a stream is driven naively through snapshots:

* rebuilding a :class:`GraphSnapshot` per batch is O(m) host work, and the
  snapshot's edge count ``m`` lives in its pytree aux — so the fused driver
  retraces on nearly every batch;
* a freshly built pull matrix changes ``tiles.shape`` / ``max_tiles`` per
  batch, retracing again.

:class:`StreamRunner` removes both.  It snapshots the graph **once**, then
maintains every engine operand incrementally in O(batch) per step:

* the capacity-padded pull matrix via
  :class:`repro.core.incremental.IncrementalPullMatrix` (tile pool and slot
  tables on the growth ladder → stable shapes; values patched by one jitted
  device scatter);
* the per-vertex out-degree vector, the per-block degree vectors and the
  tile-presence adjacency as *device-resident mirrors* patched by one
  jitted O(batch) scatter (:func:`_apply_operand_delta`) — graph-sized
  operands never re-cross the host↔device boundary (the numpy twins in
  ``IncrementalPullMatrix.aux`` stay maintained for non-stream callers);
* the initial affected frontier (paper Alg. 1 lines 4-6) by OR-semiring
  tile SpMVs over the pre- and post-batch matrices
  (:func:`_seed_affected`) — no snapshot edge arrays needed, and the
  launch is restricted to the batch's candidate blocks.

After the first batch warms the jit caches, a stream of equally-bucketed
batches re-enters the compiled ``pallas_engine._driver`` with **zero
retraces** (asserted in ``tests/test_stream.py``), and per-batch latency is
frontier-proportional: delta scatter O(batch), frontier seed O(candidate
blocks), convergence sweeps O(active blocks) — nothing scales with ``m``
except the (host-side, numpy) edge-set bookkeeping.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import faults as flt
from repro.core import frontier as fr
from repro.core import pallas_engine as pe
from repro.core.blocked import SweepStats
from repro.core.delta import signed_edge_delta
from repro.core.graph import HostGraph, initial_ranks
from repro.core.incremental import IncrementalPullMatrix, effective_batch
from repro.kernels.block_spmv import ops


@partial(jax.jit, static_argnames=("block_size", "interpret", "backend"))
def _seed_affected(mat_prev: ops.BlockSparse, mat_new: ops.BlockSparse,
                   bmat, batch, valid, *, block_size: int, interpret: bool,
                   backend: str) -> jnp.ndarray:
    """Initial DF frontier for one batch (paper Alg. 1 lines 4-6): mark the
    out-neighbors of every update source in G^{t-1} *and* G^t.

    Both graphs are queried through their pull matrices (A[v,u] ≥ 1 iff
    edge u→v, self-loops included — the same edge set a snapshot's
    ``out_neighbor_or`` walks), so the stream needs no snapshot edge
    arrays.  Launches are restricted to the candidate row-blocks that own a
    tile in a source's column-block; ``mat_new``'s structure is a superset
    of ``mat_prev``'s (growth is monotone), so one candidate set covers
    both passes."""
    n_pad = valid.shape[0]
    n_rb = n_pad // block_size
    ind = jnp.zeros((n_pad + 1,), bool)
    ind = ind.at[jnp.minimum(batch[:, 0], n_pad)].set(True)
    f = ind[:n_pad] & valid
    sb = fr.block_any(f, n_rb, block_size)
    cand = (bmat & sb[None, :]).any(axis=1)
    n_cand = cand.sum()
    cids = fr.compact_block_ids(cand, n_rb)
    fx = f.astype(mat_new.tiles.dtype)
    h_prev = ops.block_spmv_active_bucketed(
        mat_prev, fx, cids, n_cand, semiring="or", interpret=interpret,
        backend=backend)
    h_new = ops.block_spmv_active_bucketed(
        mat_new, fx, cids, n_cand, semiring="or", interpret=interpret,
        backend=backend)
    return (((h_prev > 0) | (h_new > 0))
            & jnp.repeat(cand, block_size) & valid)


@partial(jax.jit, static_argnames=("block",))
def _apply_operand_delta(out_deg, rb_in, rb_out, bmat,
                         rows, cols, vals, *, block: int):
    """O(batch) device-side update of the engine-operand mirrors from the
    signed pull-layout delta (rows = dst, cols = src, vals = ±1; padded
    entries carry val 0 and are inert).  Mirrors
    :meth:`repro.core.incremental.MatrixAux.apply_delta` plus the
    out-degree update, so a stream never re-uploads the graph-sized
    operand vectors — only the bucketed batch crosses to the device."""
    n_pad = out_deg.shape[0]
    n_rb = rb_in.shape[0]
    real = vals != 0
    v = jnp.where(real, vals, 0).astype(rb_in.dtype)
    rb = jnp.minimum(rows // block, n_rb - 1)
    cb = jnp.minimum(cols // block, n_rb - 1)
    out_deg = out_deg.at[jnp.minimum(cols, n_pad - 1)].add(
        v.astype(out_deg.dtype))
    rb_in = rb_in.at[rb].add(v)
    rb_out = rb_out.at[cb].add(v)
    # OR-scatter: padded entries contribute max(existing, False) == existing
    bmat = bmat.at[rb, cb].max(real)
    return out_deg, rb_in, rb_out, bmat


@dataclasses.dataclass
class StreamBatchResult:
    """Outcome of one stream step."""
    ranks: jnp.ndarray            # [n_pad] post-batch converged ranks
    stats: SweepStats
    wall_time_s: float            # full step: delta + seed + converge
    batch_edges: int              # raw batch size (before no-op filtering)
    driver_cache_size: int        # jit cache entries of the fused driver


@dataclasses.dataclass
class StreamReport:
    """Aggregate latency/compile statistics over a stream."""
    results: List[StreamBatchResult]
    wall_times_s: List[float]
    p50_s: float
    p95_s: float
    retraces_post_warmup: int     # driver cache growth after batch 1

    @property
    def final_ranks(self) -> jnp.ndarray:
        return self.results[-1].ranks


def _driver_cache_size() -> int:
    try:
        return int(pe._driver._cache_size())
    except Exception:           # pragma: no cover - older jax fallback
        return -1


class StreamRunner:
    """Drives DF_LF PageRank along a dynamic edge stream with a
    recompile-free, frontier-proportional per-batch hot path.

    Usage::

        runner = StreamRunner(hg0, block_size=64)
        for dels, ins in batches:
            res = runner.step(dels, ins)     # converged ranks + latency
        # or: report = run_stream(hg0, batches)

    The vertex set (and hence the block grid) is fixed for the lifetime of
    the runner; growing past it requires a new runner.  Rank state warm
    starts each batch from the previous converged vector (the dynamic
    PageRank setting).  ``r0=None`` runs one static solve on the initial
    graph (also serving as the engine warmup).
    """

    def __init__(self, hg0: HostGraph, *, block_size: int = 64,
                 dtype=np.float64, r0: Optional[jnp.ndarray] = None,
                 mode: str = "lf", active_policy: str = "affected",
                 alpha: float = 0.85, tau: float = 1e-10,
                 tau_f: Optional[float] = None, max_iterations: int = 500,
                 interpret: Optional[bool] = None,
                 backend: Optional[str] = None):
        if mode not in ("lf", "bb"):
            raise ValueError(mode)
        self.hg = hg0
        # the ONLY snapshot the runner ever builds; not retained — the
        # scalars + per-vertex/per-block operand mirrors below carry
        # everything the hot path needs
        g0 = hg0.snapshot(block_size=block_size)
        self.n, self.n_pad = g0.n, g0.n_pad
        self.block_size, self.n_rb = g0.block_size, g0.n_blocks
        self.mode, self.active_policy = mode, active_policy
        self.max_iterations = max_iterations
        self.interpret = (pe.default_interpret() if interpret is None
                          else interpret)
        self.backend = ops._resolve_backend(backend)
        dt = jnp.dtype(dtype)
        if tau_f is None:
            tau_f = tau / 1000.0
        # traced hyperparameter operands, created once so dtypes (and the
        # jit cache key) are identical across every step
        self._alpha = jnp.asarray(alpha, dt)
        self._tau = jnp.asarray(tau, dt)
        self._tau_f = jnp.asarray(tau_f, dt)
        t = flt.NO_FAULTS.device_tables(max_iterations)
        self._fault_tables = tuple(jnp.asarray(a) for a in t)

        self.inc = IncrementalPullMatrix.from_snapshot(
            g0, dtype=np.dtype(dtype), padded=True)
        self.valid = g0.vertex_valid
        # device-resident engine operands, patched in place per batch by
        # _apply_operand_delta (the host-side numpy twins live in
        # inc.aux for non-stream callers)
        self._out_deg = jnp.asarray(g0.out_deg)
        self._rb_in = jnp.asarray(self.inc.aux.rb_in)
        self._rb_out = jnp.asarray(self.inc.aux.rb_out)
        self._bmat = jnp.asarray(self.inc.aux.bmat)
        if r0 is None:
            r0, _ = pe.run_pallas(
                g0, initial_ranks(g0, dt), g0.vertex_valid, mode=mode,
                expand=False, alpha=alpha, tau=tau,
                max_iterations=max_iterations, active_policy=active_policy,
                mat=self.inc.mat, aux=self.inc.aux,
                interpret=self.interpret, backend=self.backend)
        self.R = jnp.asarray(r0, dt)[:self.n_pad]

    def warmup(self) -> None:
        """Trace the full per-batch pipeline at the stream's operand shapes
        without perturbing graph or rank state: a zero-value delta against
        vertex 0's (always present) self-loop tile warms the device scatter
        at the base batch bucket, and an empty-batch step warms the frontier
        seed and the fused driver.  Batches larger than the base bucket
        (64 edges) still pay one compile per new bucket they reach."""
        z = np.zeros(1, np.int64)
        self.inc.mat = ops.apply_delta(self.inc.mat, z, z, np.zeros(1))
        empty = np.zeros((0, 2), np.int64)
        self.step(empty, empty)

    def step(self, deletions: np.ndarray, insertions: np.ndarray
             ) -> StreamBatchResult:
        """Apply one edge batch and reconverge: delta scatter → frontier
        seed → fused convergence loop, all device-side after the O(batch)
        host bookkeeping.  Returns the converged ranks and latency stats."""
        t0 = time.perf_counter()
        mat_prev = self.inc.mat
        dels_eff, ins_eff = effective_batch(self.hg, deletions, insertions)
        mat_new = self.inc.advance(self.hg, None, deletions, insertions,
                                   effective=(dels_eff, ins_eff))
        self.hg = self.hg.apply_batch(deletions, insertions)

        # patch the device-resident operand mirrors in O(batch): only the
        # bucketed signed delta crosses host→device, never the graph-sized
        # vectors
        rows, cols, vals = signed_edge_delta(dels_eff, ins_eff)
        if len(rows):
            b_pad = ops.capacity_bucket(len(rows), ops.DELTA_BATCH_BUCKET)
            z = np.zeros(b_pad - len(rows), np.int32)
            self._out_deg, self._rb_in, self._rb_out, self._bmat = \
                _apply_operand_delta(
                    self._out_deg, self._rb_in, self._rb_out, self._bmat,
                    jnp.asarray(np.concatenate(
                        [rows.astype(np.int32), z])),
                    jnp.asarray(np.concatenate(
                        [cols.astype(np.int32), z])),
                    jnp.asarray(np.concatenate(
                        [vals.astype(np.int32), z])),
                    block=self.block_size)

        batch_dev = fr.pack_batch(self.n_pad, deletions, insertions)
        affected = _seed_affected(
            mat_prev, mat_new, self._bmat, batch_dev, self.valid,
            block_size=self.block_size, interpret=self.interpret,
            backend=self.backend)

        part, alive, delay, crashed = self._fault_tables
        R, stats_vec = pe._driver(
            mat_new, self.R, affected, self.valid, self._out_deg,
            self._rb_in, self._rb_out, self._bmat,
            self._alpha, self._tau, self._tau_f,
            part, alive, delay, crashed,
            n=self.n, block_size=self.block_size, mode=self.mode,
            expand=True, active_policy=self.active_policy,
            max_iterations=self.max_iterations, interpret=self.interpret,
            backend=self.backend)
        sv = np.asarray(jax.block_until_ready(stats_vec))  # the single sync
        self.R = R
        raw = (np.asarray(deletions).reshape(-1, 2).shape[0]
               + np.asarray(insertions).reshape(-1, 2).shape[0])
        return StreamBatchResult(
            ranks=R, stats=pe._stats_from_vec(sv),
            wall_time_s=time.perf_counter() - t0, batch_edges=raw,
            driver_cache_size=_driver_cache_size())


def run_stream(hg0: HostGraph,
               batches: Iterable[Tuple[np.ndarray, np.ndarray]],
               warmup: bool = True, **runner_kwargs) -> StreamReport:
    """Run a whole stream of (deletions, insertions) batches and aggregate
    per-batch latency (p50/p95) and post-warmup retrace counts.  Keyword
    arguments are forwarded to :class:`StreamRunner`.

    ``warmup=True`` runs :meth:`StreamRunner.warmup` first (not recorded):
    it traces the delta/seed/driver pipeline at the stream's operand shapes
    without perturbing the graph, so recorded latencies are steady-state
    (up to batches reaching a not-yet-seen size bucket) and the retrace
    count covers **every** recorded batch, including the first."""
    runner = StreamRunner(hg0, **runner_kwargs)
    if warmup:
        runner.warmup()
    base = _driver_cache_size() if warmup else -1
    results: List[StreamBatchResult] = []
    for dels, ins in batches:
        results.append(runner.step(dels, ins))
    if not results:
        raise ValueError("empty stream")
    walls = [r.wall_time_s for r in results]
    caches = [r.driver_cache_size for r in results]
    # with a warmup, any driver compile during a recorded batch counts as a
    # retrace; without one, the first batch's (expected) trace is excluded
    if not caches or caches[-1] < 0:
        retraces = -1
    elif warmup and base >= 0:
        retraces = caches[-1] - base
    else:
        retraces = caches[-1] - caches[0]
    return StreamReport(
        results=results, wall_times_s=walls,
        p50_s=float(np.percentile(walls, 50)),
        p95_s=float(np.percentile(walls, 95)),
        retraces_post_warmup=retraces)
