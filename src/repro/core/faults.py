"""Deterministic thread-fault schedules (paper §5.1.6, §5.3, §5.4).

This module is the *thread* blast radius of the unified fault-domain
abstraction (:mod:`repro.core.fault_domain`, docs/FAULTS.md): it generates
the deterministic per-(pseudo-thread, sweep) delay/crash tables the sweep
engines consume.  Shard- and process-level faults live in their own
domains; construct them through ``fault_domain.ShardFaultDomain`` /
``EngineConfig(durability="wal")`` respectively.

The paper simulates (a) random thread *delays* — a thread sleeps for D ms with
probability p per vertex processed — and (b) *crash-stop* failures — a flagged
thread deterministically stops participating.

On TPU there are no preemptible threads; the sweep engine assigns compacted
block slots round-robin to ``n_threads`` *pseudo-threads* and a ``FaultPlan``
decides, per (pseudo-thread, sweep), whether that thread's slots are processed.
Unprocessed blocks keep their convergence flags set and are re-covered by
surviving capacity on later sweeps — exactly the paper's recovery argument.

A simulated-time model converts per-thread work into wall-clock analogues so
Figs 6/8/9 can be reproduced without real multicore scheduling:
    sweep_time(LF) = max over *alive* threads of (edges·t_edge + blocks·t_block
                     + delay·1[delayed])
    iter_time(BB)  = max over *all* threads of the same (delayed threads still
                     finish before the barrier; a crashed thread stalls the
                     barrier forever → DNF).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# calibration constants for the simulated-time model (arbitrary but fixed;
# results are reported as ratios, mirroring the paper's relative plots)
T_EDGE_NS = 1.0        # per-edge processing cost
T_BLOCK_NS = 2000.0    # per-block scheduling overhead


@dataclasses.dataclass
class FaultPlan:
    """Deterministic per-(thread, sweep) fault schedule."""

    n_threads: int = 64
    delay_prob: float = 0.0       # per-thread, per-sweep delay probability
    delay_ms: float = 0.0
    n_crashed: int = 0            # number of threads that crash
    crash_window: int = 64        # crashes occur at a random sweep in [0, w)
    seed: int = 0
    max_sweeps: int = 4096

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        self._delays = (rng.random((self.max_sweeps, self.n_threads))
                        < self.delay_prob)
        crash_at = np.full(self.n_threads, np.iinfo(np.int64).max)
        if self.n_crashed:
            who = rng.choice(self.n_threads, size=min(self.n_crashed,
                                                      self.n_threads),
                             replace=False)
            crash_at[who] = rng.integers(0, max(1, self.crash_window),
                                         size=len(who))
        self._crash_at = crash_at

    # -- queries -------------------------------------------------------------
    def alive(self, sweep: int) -> np.ndarray:
        return self._crash_at > sweep

    def delayed(self, sweep: int) -> np.ndarray:
        s = min(sweep, self.max_sweeps - 1)
        return self._delays[s] & self.alive(sweep)

    def participating(self, sweep: int) -> np.ndarray:
        """Threads that actually process their slots this sweep (LF)."""
        return self.alive(sweep) & ~self.delayed(sweep)

    def any_crashed(self, sweep: int) -> bool:
        return bool((~self.alive(sweep)).any())

    # -- simulated time -------------------------------------------------------
    def sweep_time_ms(self, sweep: int, thread_edges: np.ndarray,
                      thread_blocks: np.ndarray, *, barrier: bool) -> float:
        """Simulated duration of one sweep/iteration, in milliseconds."""
        work_ms = (thread_edges * T_EDGE_NS
                   + thread_blocks * T_BLOCK_NS) * 1e-6
        delay = self.delayed(sweep) * self.delay_ms
        if barrier:
            # delayed threads still finish before the barrier; everyone waits
            return float(np.max(work_ms + delay))
        alive = self.alive(sweep)
        if not alive.any():
            return 0.0
        return float(np.max(np.where(alive, work_ms, 0.0)))


    # -- device export (fused engine) ----------------------------------------
    def device_tables(self, max_iterations: int):
        """Precompute the per-(sweep, thread) fault schedule as dense arrays
        so a fully on-device driver can apply fault masks with zero host
        syncs: (participating, alive, delay_ms_row, any_crashed)."""
        s = min(max_iterations, self.max_sweeps)
        sweeps = np.arange(s)
        alive = self._crash_at[None, :] > sweeps[:, None]
        delayed = self._delays[:s] & alive
        part = alive & ~delayed
        delay_row = delayed * self.delay_ms
        crashed = (~alive).any(axis=1)
        if s < max_iterations:                      # clamp-extend final row
            def ext(a):
                return np.concatenate(
                    [a, np.repeat(a[-1:], max_iterations - s, axis=0)], 0)
            alive, part, delay_row, crashed = map(
                ext, (alive, part, delay_row, crashed))
        return (part.astype(bool), alive.astype(bool),
                delay_row.astype(np.float32), crashed.astype(bool))


NO_FAULTS = FaultPlan(n_threads=1)


def slot_thread_assignment(n_slots: int, n_threads: int) -> np.ndarray:
    """Round-robin slot → pseudo-thread map (the paper's dynamic chunk pool)."""
    return np.arange(n_slots, dtype=np.int64) % max(1, n_threads)
