"""Fused residual forward-push driver — work ∝ residual mass, not sweeps.

The pull driver (:mod:`repro.core.pallas_engine`) re-pulls every active
row-block until the whole iterate converges: a localized delta batch still
pays ~``log(tau)/log(alpha)`` sweeps over the full frontier, so per-batch
edge work is frontier cardinality × sweep count.  Forward push (Zhang et
al., *Two Parallel PageRank Algorithms via Improving Forward Push*;
Andersen–Chung–Lang style residuals) inverts the accounting: the session
keeps an explicit **residual vector** ``r`` next to the rank estimate
``p``, maintaining the exact invariant

    r = b + M·p − p,      b = (1−α)/n on valid vertices,
                          M = α · A · D⁻¹  (pull matrix, self-loops incl.)

and each sweep *pushes* only the residual of blocks still holding an
above-tolerance entry.  Pushing source set S moves
``p ← p + r·1_S`` and ``r ← r − r·1_S + α·A·D⁻¹·(r·1_S)``, which
preserves the invariant exactly and shrinks ``‖r‖₁`` by
``(1−α)·‖r·1_S‖₁`` — so total edge work is proportional to the seeded
residual mass (O(batch-sized) after a delta), while the fixed point
``p = b + M·p`` is the same PageRank vector the pull driver converges to,
with L∞ error bounded by ``‖r‖₁ · α/(1−α)`` at exit.

Everything rides the existing streaming machinery:

* the push is :func:`repro.kernels.block_spmv.ops.block_spmv_push_bucketed`
  — the scatter semiring realized on the SAME capacity-padded
  ``BlockSparse`` tile pool and slot tables as the pull (``A @ (x ⊙ 1_S)``),
  launched over the candidate destination row-blocks from the
  tile-presence adjacency at the static active-count ladder;
* source selection is bucketed top-mass: the smallest ladder bucket
  K ≥ |pushable| picks the K heaviest blocks by residual mass through a
  ``lax.switch`` (K ≥ |pushable|, so selection is complete — the bucket
  bounds the top-k cost and keeps every launch shape static and
  retrace-free);
* one ``lax.while_loop`` with zero host syncs; convergence is the
  per-vertex residual bound (``max|r| ≤ tau`` — pushing v moves p[v] by
  exactly r[v], so this is the same strength as the pull driver's
  ``maxdr ≤ tau`` stop) plus the PR-9 ulp-floor escape
  (``max|r| ≤ 16·eps·max|p|`` — the regime where pushes are no longer
  representable in ``p``); ``‖r‖₁`` is still reported, giving the
  computable a-posteriori L∞ bound ``‖r‖₁·α/(1−α)``;
* tiering composes without mid-sweep syncs: a push delivers to the
  device-*resident* candidate destination rows only; a pushed-to
  non-resident row goes **stale** and is recorded in the PR-9 deferred
  bitmap.  Nothing is lost: the rank estimate ``p`` is always globally
  exact (advancing ``p`` needs no tiles), so a stale row's residual is
  recomputed *exactly* from the invariant — ``r = b + M·p − p`` needs
  only the row's own tile row, which IS resident once the session's
  refill loop admits it (:func:`residual_refresh_blocks`).

Delta seeding is O(batch·deg): a batch changing M → M' shifts the
residual by exactly ``Δr = (M' − M)·p``, which touches only the changed
source columns — :func:`residual_seed_host` enumerates it from the sorted
host key sets and one bucketed device scatter applies it
(:func:`scatter_residual`).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import frontier as fr
from repro.core.blocked import SweepStats
from repro.core.graph import HostGraph
from repro.kernels.block_spmv import ops

# stats vector layout returned by _push_driver
STATS_LEN = 8   # sweeps, pushed_blocks, cand_blocks, edges, l1, maxr,
#                 converged, stalled


@partial(jax.jit, static_argnames=("n", "block_size", "max_iterations",
                                   "interpret", "backend", "tiered"))
def _push_driver(mat: ops.BlockSparse, P0, R0, valid, out_deg, rb_out,
                 bmat, rb_res, alpha, tau, *,
                 n: int, block_size: int, max_iterations: int,
                 interpret: bool, backend: str, tiered: bool = False):
    """The fused push loop.  Returns (p [n_pad], r [n_pad], stats vector
    [STATS_LEN], deferred row-block indicator [n_rb]).

    ``P0`` is the rank estimate and ``R0`` the residual satisfying
    ``r = b + M·p − p`` (the caller maintains it via seeding or full
    recompute).  Operand shapes are stable across a stream — same
    zero-retrace contract as the pull driver.

    ``tiered=True``: ``rb_res`` marks resident row-blocks.  Pushes deliver
    to resident candidate destination rows only; a pushed-to non-resident
    row goes stale and is marked in ``deferred`` (never a mid-sweep sync)
    — the caller's refill loop admits it and rebuilds its residual exactly
    via :func:`residual_refresh_blocks` (``p`` stays globally exact, so
    staleness is confined to ``r`` on marked rows).
    """
    dtype = P0.dtype
    B = block_size
    n_pad = valid.shape[0]
    n_rb = n_pad // B
    cdt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    ladder = ops.active_ladder(n_rb)
    eps = float(np.finfo(np.dtype(dtype)).eps)

    deg = jnp.maximum(out_deg, 1).astype(dtype)
    inv_deg = jnp.where(valid, 1.0 / deg, 0).astype(dtype)
    alpha_c = alpha.astype(dtype)
    tau_c = tau.astype(dtype)
    base_floor = (1.0 - alpha_c) / n

    P = jnp.where(valid, P0[:n_pad], 0).astype(dtype)
    Rr = jnp.where(valid, R0[:n_pad], 0).astype(dtype)

    def cond(state):
        (_, _, it, converged, stalled, _, _) = state
        return ~converged & ~stalled & (it < max_iterations)

    def body(state):
        P, Rr, it, converged, stalled, deferred, ctr = state
        aRr = jnp.abs(Rr).reshape(n_rb, B)
        rb_mass = aRr.sum(axis=1)
        rb_maxr = aRr.max(axis=1)
        maxr = rb_maxr.max()
        # ulp-floor escape (PR-9 maxdr analogue): every remaining residual
        # is below the rounding granularity of p — pushing cannot move p
        at_floor = maxr <= 16.0 * eps * jnp.maximum(jnp.abs(P).max(),
                                                    base_floor)
        # per-vertex exit: pushing v moves p[v] by exactly r[v], so
        # max|r| ≤ tau is the same strength as the pull driver's
        # maxdr ≤ tau stop — no vertex's next move would exceed tau
        conv_now = (maxr <= tau_c) | at_floor
        pushable = rb_maxr > tau_c
        n_push = pushable.sum()
        do = ~conv_now & (n_push > 0)
        # defensive only: maxr > tau with every per-block max ≤ tau is
        # impossible (maxr IS the max over the per-block maxima)
        stall_now = ~conv_now & (n_push == 0)

        # -- bucketed top-mass source selection: smallest ladder bucket
        #    K ≥ |pushable|, top-K blocks by residual mass via lax.switch.
        #    K ≥ |pushable| makes selection complete; the bucket bounds the
        #    top-k cost and keeps the trace static (retrace-free). --------
        mass_m = jnp.where(pushable, rb_mass, -1.0)

        def sel_at(K):
            vals, ids = lax.top_k(mass_m, K)
            keep = vals > 0
            sel_p = jnp.zeros((n_rb + 1,), bool)
            sel_p = sel_p.at[jnp.where(keep, ids, n_rb)].set(True)
            return sel_p[:n_rb]

        if len(ladder) == 1:
            sel = sel_at(ladder[0])
        else:
            branches = [partial(sel_at, K) for K in ladder]
            bidx = sum((n_push > K).astype(jnp.int32)
                       for K in ladder[:-1])
            sel = lax.switch(bidx, branches)
        sel = sel & do

        # -- the push: scatter-semiring SpMV over candidate dst blocks.
        #    Per-vertex threshold (Andersen–Chung–Lang form): only entries
        #    with |r| > tau move — sub-tau entries stay in r, which is
        #    exactly what the max|r| ≤ tau exit permits — so edge work is
        #    Σ out-deg over *pushed vertices*, not over whole blocks. ------
        sel_v = jnp.repeat(sel, B) & valid & (jnp.abs(Rr) > tau_c)
        cand = (bmat & sel[None, :]).any(axis=1)
        if tiered:
            # deliver to resident destination rows only; a pushed-to
            # non-resident row goes stale → deferred bitmap (the refill
            # loop admits it and recomputes r = b + M·p − p exactly —
            # never a mid-sweep sync).  sel is already zero on converged
            # iterations, so cand carries the ~conv gate.
            deferred = deferred | (cand & ~rb_res)
            cand_rb = cand & rb_res
        else:
            cand_rb = cand
        n_cand = jnp.where(do, cand_rb.sum(), 0)
        cids = jnp.where(do, fr.compact_block_ids(cand_rb, n_rb), -1)
        moved = jnp.where(sel_v, Rr, 0)
        pushed = ops.block_spmv_push_bucketed(
            mat, moved * inv_deg, sel, cids, n_cand,
            interpret=interpret, backend=backend, ladder=ladder)
        pushed = jnp.where(jnp.repeat(cand_rb, B) & valid & do, pushed, 0)
        P1 = P + moved
        R1 = Rr - moved + alpha_c * pushed

        sweeps, pushed_b, cand_b, edges = ctr
        # edge work = out-edges of the vertices actually pushed this sweep
        e_sweep = jnp.where(sel_v, out_deg, 0).astype(cdt).sum()
        ctr1 = (sweeps + jnp.where(do, 1, 0).astype(cdt),
                pushed_b + jnp.where(do, n_push, 0).astype(cdt),
                cand_b + n_cand.astype(cdt),
                edges + e_sweep)
        return (P1, R1, it + 1, converged | conv_now,
                stalled | stall_now, deferred, ctr1)

    zero = jnp.zeros((), cdt)
    init = (P, Rr, jnp.int32(0), jnp.asarray(False), jnp.asarray(False),
            jnp.zeros((n_rb,), bool), (zero, zero, zero, zero))
    P, Rr, _, converged, stalled, deferred, ctr = lax.while_loop(
        cond, body, init)
    sweeps, pushed_b, cand_b, edges = ctr
    fdt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    stats = jnp.stack([
        sweeps.astype(fdt), pushed_b.astype(fdt), cand_b.astype(fdt),
        edges.astype(fdt), jnp.abs(Rr).sum().astype(fdt),
        jnp.abs(Rr).max().astype(fdt), converged.astype(fdt),
        stalled.astype(fdt)])
    return P, Rr, stats, deferred


def push_stats_from_vec(sv: np.ndarray) -> Tuple[SweepStats, dict]:
    """Split the driver's stats vector into the engine-common
    :class:`SweepStats` plus the push-specific extras."""
    stats = SweepStats(
        sweeps=int(sv[0]), iterations=int(sv[0]),
        blocks_processed=int(sv[2]), edges_processed=int(sv[3]),
        sim_time_ms=0.0, converged=bool(sv[6] > 0), dnf=False)
    extras = {"pushed_blocks": int(sv[1]),
              "residual_l1": float(sv[4]),
              "max_residual": float(sv[5]),
              "stalled": bool(sv[7] > 0)}
    return stats, extras


def push_cache_size() -> int:
    """Jit-cache entries of the push driver (the push session's retrace
    yardstick — separate from the pull driver's cache)."""
    try:
        return int(_push_driver._cache_size())
    except Exception:           # pragma: no cover - older jax fallback
        return -1


# ---------------------------------------------------------------------------
# residual maintenance: O(batch·deg) delta seeding + full recompute
# ---------------------------------------------------------------------------

def residual_seed_host(hg_prev: HostGraph, hg_cur: HostGraph,
                       sources: np.ndarray, p_src: np.ndarray,
                       deg_old: np.ndarray, deg_new: np.ndarray,
                       alpha: float) -> Tuple[np.ndarray, np.ndarray]:
    """Exact residual shift for one delta batch, enumerated host-side.

    A batch changes M → M' only in the columns of its (effective) source
    vertices, so ``Δr = (M' − M)·p`` is, per source u:

        r[v] −= α·p[u]/deg_old(u)   for v ∈ N_old(u) ∪ {u}
        r[v] += α·p[u]/deg_new(u)   for v ∈ N_new(u) ∪ {u}

    (the ∪{u} term is the per-vertex self-loop every device graph
    carries; ``deg_*`` already count it).  Neighbor lists come from the
    sorted host key sets — O(batch·deg) work, no snapshot.  Returns a
    flat (indices, values) scatter list for :func:`scatter_residual`."""
    sources = np.asarray(sources, np.int64).reshape(-1)
    p_src = np.asarray(p_src)
    idx_parts, val_parts = [], []
    for hg, deg, sgn in ((hg_prev, deg_old, -1.0), (hg_cur, deg_new, 1.0)):
        n = np.int64(hg.n)
        keys = hg._keys
        lo = np.searchsorted(keys, sources * n)
        hi = np.searchsorted(keys, (sources + 1) * n)
        counts = (hi - lo).astype(np.int64)
        total = int(counts.sum())
        flat = np.empty(total, np.int64)
        off = 0
        for k0, k1 in zip(lo.tolist(), hi.tolist()):
            if k1 > k0:
                flat[off:off + (k1 - k0)] = keys[k0:k1] % n
                off += k1 - k0
        scale = (sgn * alpha) * p_src / np.maximum(
            np.asarray(deg, p_src.dtype), 1)
        idx_parts += [flat, sources]
        val_parts += [np.repeat(scale, counts), scale]
    return (np.concatenate(idx_parts),
            np.concatenate(val_parts).astype(p_src.dtype))


@jax.jit
def _scatter_residual(Rr, idx, vals):
    n_pad = Rr.shape[0]
    tmp = jnp.zeros((n_pad + 1,), Rr.dtype).at[:n_pad].set(Rr)
    tmp = tmp.at[idx].add(vals.astype(Rr.dtype))
    return tmp[:n_pad]


def scatter_residual(Rr, idx: np.ndarray, vals: np.ndarray):
    """Apply a host-enumerated residual shift with one bucketed device
    scatter: the index/value lists are padded to the delta-batch bucket
    (pad slots target the guard row), so only O(batch·deg) crosses
    host→device and the jit cache stays O(log) in batch size."""
    idx = np.asarray(idx, np.int64).reshape(-1)
    vals = np.asarray(vals).reshape(-1)
    n_pad = int(Rr.shape[0])
    k = ops.capacity_bucket(max(len(idx), 1), ops.DELTA_BATCH_BUCKET)
    pi = np.full(k, n_pad, np.int64)
    pv = np.zeros(k, np.dtype(Rr.dtype))
    pi[:len(idx)] = idx
    pv[:len(vals)] = vals
    return _scatter_residual(Rr, jnp.asarray(pi), jnp.asarray(pv))


@partial(jax.jit, static_argnames=("n", "interpret", "backend"))
def residual_full(mat: ops.BlockSparse, P, valid, out_deg, alpha, *,
                  n: int, interpret: bool, backend: str):
    """Full residual recompute on the device matrix:
    ``r = b + α·A·D⁻¹·p − p`` (the nd / restore / repair path — O(m),
    exact, no seeding history needed)."""
    dtype = P.dtype
    deg = jnp.maximum(out_deg, 1).astype(dtype)
    inv_deg = jnp.where(valid, 1.0 / deg, 0).astype(dtype)
    alpha_c = alpha.astype(dtype)
    base = (1.0 - alpha_c) / n
    Pm = jnp.where(valid, P, 0).astype(dtype)
    pulled = ops.block_spmv(mat, Pm * inv_deg, semiring="sum",
                            interpret=interpret, backend=backend)
    return jnp.where(valid, base + alpha_c * pulled - Pm, 0)


@partial(jax.jit, static_argnames=("n", "block_size", "interpret",
                                   "backend"))
def residual_refresh_blocks(mat: ops.BlockSparse, P, Rr, valid, out_deg,
                            alpha, ids, n_ids, *, n: int, block_size: int,
                            interpret: bool, backend: str):
    """Exact residual rebuild restricted to the given row-blocks:
    ``r[rb] = b + α·(A·D⁻¹·p)[rb] − p[rb]`` for each id (the tiered
    refill path — a stale, just-admitted block needs only its OWN tile
    row, and ``p`` is always globally exact).  ``ids`` is a [n_rb]
    -1-padded compact list, ``n_ids`` the traced live count; launches ride
    the same bucketed active-SpMV ladder as the drives, so admitting any
    number of blocks stays retrace-free."""
    dtype = P.dtype
    n_rb = valid.shape[0] // block_size
    deg = jnp.maximum(out_deg, 1).astype(dtype)
    inv_deg = jnp.where(valid, 1.0 / deg, 0).astype(dtype)
    alpha_c = alpha.astype(dtype)
    base = (1.0 - alpha_c) / n
    Pm = jnp.where(valid, P, 0).astype(dtype)
    pulled = ops.block_spmv_active_bucketed(
        mat, Pm * inv_deg, ids, n_ids, semiring="sum",
        interpret=interpret, backend=backend)
    sel = jnp.zeros((n_rb + 1,), bool)
    sel = sel.at[jnp.where(ids >= 0, ids, n_rb)].set(True)[:n_rb]
    rows = jnp.repeat(sel, block_size) & valid
    return jnp.where(rows, base + alpha_c * pulled - Pm, Rr)


def residual_from_host(hg: HostGraph, out_deg: np.ndarray, p: np.ndarray,
                       alpha: float) -> np.ndarray:
    """Full residual recompute from host truth (tiered sessions: the
    device matrix is only a partial hot-set view, so the O(m) recompute
    walks the host key set instead — self-loops added explicitly)."""
    n = hg.n
    keys = hg._keys
    src = (keys // n).astype(np.int64)
    dst = (keys % n).astype(np.int64)
    p = np.asarray(p)
    deg = np.maximum(np.asarray(out_deg[:n], np.float64), 1)
    contrib = float(alpha) * np.asarray(p[:n], np.float64) / deg
    pulled = np.bincount(dst, weights=contrib[src], minlength=n)
    pulled += contrib           # the per-vertex self-loops
    r = (1.0 - float(alpha)) / n + pulled - np.asarray(p[:n], np.float64)
    out = np.zeros(p.shape[0], p.dtype)
    out[:n] = r.astype(p.dtype)
    return out
