"""Analytic MODEL_FLOPS per cell — the *useful* flops of one full step.

Conventions (standard MFU accounting):
  * matmul = 2·m·n·k flops; elementwise/norm/softmax flops are ignored;
  * remat recomputation is EXCLUDED (that waste is exactly what the
    MODEL_FLOPS / HLO_FLOPs ratio in §Roofline is meant to expose);
  * training = 3 × forward (backward is 2×); embedding *gather* is free,
    the vocab-head matmul is counted;
  * MoE counts only the top-k active experts (6·N_active·D);
  * causal attention counts the ~half of the score matrix actually computed;
    sliding-window attention counts ≤window keys per query.

All numbers are GLOBAL flops for the full step (the roofline divides by
chips × peak).
"""
from __future__ import annotations

from typing import Any, Dict

from repro.configs.registry import ArchSpec, ShapeSpec


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def lm_matmul_params(cfg, *, active: bool = True) -> int:
    """Matmul-participating params (norms excluded, head included)."""
    D, H, KV, dh, F, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.d_head, cfg.d_ff, cfg.n_layers)
    attn = D * H * dh + 2 * D * KV * dh + H * dh * D
    n_mats = 3 if cfg.mlp == "swiglu" else 2
    if cfg.moe:
        e = cfg.moe.top_k if active else cfg.moe.n_experts
        ff = e * n_mats * D * F + D * cfg.moe.n_experts  # + router
    else:
        ff = n_mats * D * F
    head = cfg.vocab_padded * D                      # output projection
    return L * (attn + ff) + head


def lm_attn_fwd_flops(cfg, batch: int, s_q: int, s_kv: int,
                      *, causal: bool) -> float:
    """QK^T + AV forward flops across all layers."""
    window = cfg.sliding_window
    if causal and window and s_kv > window:
        eff_kv = float(window)            # each query sees ≤window keys
    elif causal and s_q == s_kv:
        eff_kv = s_kv / 2.0               # lower triangle
    else:
        eff_kv = float(min(s_kv, window) if window else s_kv)
    per_layer = 2 * 2 * batch * cfg.n_heads * s_q * eff_kv * cfg.d_head
    return cfg.n_layers * per_layer


def lm_model_flops(cfg, shape: ShapeSpec) -> float:
    B = shape.dim("global_batch")
    S = shape.dim("seq_len")
    N = lm_matmul_params(cfg)
    if shape.kind == "train":
        T = B * S
        return 6.0 * N * T + 3.0 * lm_attn_fwd_flops(cfg, B, S, S,
                                                     causal=True)
    if shape.kind == "prefill":
        T = B * S
        return 2.0 * N * T + lm_attn_fwd_flops(cfg, B, S, S, causal=True)
    if shape.kind == "decode":
        cache = min(S, cfg.sliding_window) if cfg.sliding_window else S
        return 2.0 * N * B + lm_attn_fwd_flops(cfg, B, 1, cache,
                                               causal=False)
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# GNN (forward formulas per family; train = 3 × fwd)
# ---------------------------------------------------------------------------

def _mlp_flops(rows: float, dims) -> float:
    f = 0.0
    for a, b in zip(dims[:-1], dims[1:]):
        f += 2.0 * rows * a * b
    return f


def gnn_fwd_flops(cfg, n_nodes: float, n_edges: float) -> float:
    d, L, f, o = cfg.d_hidden, cfg.n_layers, cfg.d_feat, cfg.n_out
    N, E = float(n_nodes), float(n_edges)
    if cfg.family == "gatedgcn":
        enc = 2 * N * f * d + 2 * E * max(cfg.d_edge_feat, 1) * d
        layer = 2 * d * d * (4 * E + N)          # E1,E2,E3,V on edges; U
        dec = 2 * N * d * o
        return enc + L * layer + dec
    if cfg.family == "egnn":
        enc = 2 * N * f * d
        phi_e = _mlp_flops(E, (2 * d + 1 + cfg.d_edge_feat, d, d))
        phi_x = _mlp_flops(E, (d, d, 1))
        phi_h = _mlp_flops(N, (2 * d, d, d))
        dec = 2 * N * d * o
        return enc + L * (phi_e + phi_x + phi_h) + dec
    if cfg.family == "graphsage":
        flops, d_in = 0.0, f
        for _ in range(L):
            flops += 2 * 2 * N * d_in * d        # w_self + w_neigh
            d_in = d
        return flops + 2 * N * d * o
    if cfg.family == "meshgraphnet":
        ml = cfg.mlp_layers
        enc = _mlp_flops(N, (f,) + (d,) * ml) + \
            _mlp_flops(E, (4 + cfg.d_edge_feat,) + (d,) * ml)
        layer = _mlp_flops(E, (3 * d,) + (d,) * ml) + \
            _mlp_flops(N, (2 * d,) + (d,) * ml)
        dec = _mlp_flops(N, (d,) * ml + (o,))
        return enc + L * layer + dec
    raise ValueError(cfg.family)


def gnn_sampled_fwd_flops(cfg, batch: int, fanouts) -> float:
    """GraphSAGE dense-hop minibatch: nodes processed per layer step."""
    d, f = cfg.d_hidden, cfg.d_feat
    counts = [float(batch)]
    for fo in fanouts:
        counts.append(counts[-1] * fo)
    flops, d_in = 0.0, f
    L = cfg.n_layers
    for step in range(L):
        rows = sum(counts[: L - step])
        flops += 2 * 2 * rows * d_in * d
        d_in = d
    return flops + 2 * batch * d * cfg.n_out


def gnn_model_flops(cfg, shape: ShapeSpec) -> float:
    if shape.kind == "sampled" and cfg.family == "graphsage":
        fwd = gnn_sampled_fwd_flops(cfg, shape.dim("batch_nodes"),
                                    (shape.dim("fanout1"),
                                     shape.dim("fanout2")))
    elif shape.kind == "sampled":
        b, f1, f2 = (shape.dim("batch_nodes"), shape.dim("fanout1"),
                     shape.dim("fanout2"))
        n = b * (1 + f1 + f1 * f2)
        e = b * f1 + b * f1 * f2
        fwd = gnn_fwd_flops(cfg, n, e)
    elif shape.kind == "batched_small":
        b = shape.dim("batch")
        fwd = gnn_fwd_flops(cfg, b * shape.dim("n_nodes"),
                            b * shape.dim("n_edges"))
    else:
        fwd = gnn_fwd_flops(cfg, shape.dim("n_nodes"), shape.dim("n_edges"))
    return 3.0 * fwd                     # all GNN cells are training steps


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------

def autoint_fwd_flops(cfg, batch: float, n_fields: int = None) -> float:
    F = n_fields if n_fields is not None else cfg.n_sparse
    d_int, H, da = cfg.d_interact, cfg.n_heads, cfg.d_attn
    flops, d_in = 0.0, cfg.embed_dim
    for _ in range(cfg.n_attn_layers):
        flops += 2 * batch * F * d_in * d_int * 4      # wq,wk,wv,w_res
        flops += 2 * batch * H * F * F * da * 2        # scores + apply
        d_in = d_int
    return flops + 2 * batch * F * d_int               # head


def recsys_model_flops(cfg, shape: ShapeSpec) -> float:
    if shape.kind == "train":
        return 3.0 * autoint_fwd_flops(cfg, shape.dim("batch"))
    if shape.kind == "serve":
        return autoint_fwd_flops(cfg, shape.dim("batch"))
    if shape.kind == "retrieval":
        n = shape.dim("n_candidates")
        n_item = cfg.n_sparse - cfg.n_user_fields
        user = autoint_fwd_flops(cfg, 1, cfg.n_user_fields)
        item = 2 * n * n_item * cfg.embed_dim * cfg.d_interact
        dot = 2 * n * cfg.d_interact
        return user + item + dot
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# pagerank (the paper's workload): flops per distributed sweep
# ---------------------------------------------------------------------------

def pagerank_sweep_flops(n_vertices: int, n_edges: int) -> float:
    # pull: 2 flops/edge (mul + add); expansion: ~1 flop/out-edge;
    # convergence/bookkeeping ~6/vertex
    return 3.0 * n_edges + 6.0 * n_vertices


def model_flops(spec: ArchSpec, cfg: Any, shape: ShapeSpec) -> float:
    if spec.family == "lm":
        return lm_model_flops(cfg, shape)
    if spec.family == "gnn":
        return gnn_model_flops(cfg, shape)
    if spec.family == "recsys":
        return recsys_model_flops(cfg, shape)
    if spec.family == "pagerank":
        n = shape.dim("n_vertices")
        return pagerank_sweep_flops(n, n * shape.dim("avg_degree"))
    raise ValueError(spec.family)
