"""Reduced-config smoke harness: small CONCRETE inputs per architecture.

Every assigned arch gets a reduced config (``ArchSpec.smoke_cfg``) and this
module builds matching real (allocated) inputs so one forward/train step can
run on CPU — used by ``tests/test_archs_smoke.py`` and the examples.  The
FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import ArchSpec
from repro.train import trainer


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def _random_graph_arrays(rng, *, n: int, e: int, d_feat: int, n_out: int,
                         with_pos: bool, n_graphs: int = 1,
                         task: str = "node_clf") -> Dict[str, jnp.ndarray]:
    batch = {
        "nodes": jnp.asarray(rng.normal(size=(n, d_feat)), jnp.float32),
        "senders": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "receivers": jnp.asarray(rng.integers(0, n, e), jnp.int32),
    }
    if with_pos:
        batch["pos"] = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    if task == "graph_reg":
        batch["graph_id"] = jnp.asarray(
            np.sort(rng.integers(0, n_graphs, n)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.normal(size=(n_graphs, n_out)), jnp.float32)
    elif task == "node_reg":
        batch["labels"] = jnp.asarray(rng.normal(size=(n, n_out)),
                                      jnp.float32)
    else:
        batch["labels"] = jnp.asarray(rng.integers(0, n_out, n), jnp.int32)
    return batch


def smoke_setup(spec: ArchSpec, *, seed: int = 0
                ) -> Tuple[Any, Callable, Dict, Dict]:
    """Returns (cfg, loss_fn, params, batch) for one reduced train step."""
    rng = _rng(seed)
    key = jax.random.PRNGKey(seed)
    if spec.family == "lm":
        from repro.models.transformer import model as M
        cfg = spec.smoke_cfg()
        params = M.init_params(cfg, key)
        B, S = 4, 64
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
        }
        return cfg, trainer.lm_loss(cfg), params, batch

    if spec.family == "gnn":
        from repro.models.gnn import get_family
        from repro.models.gnn.common import GraphBatch
        cfg = spec.smoke_cfg()
        mod = get_family(cfg)
        params = mod.init(cfg, key)
        with_pos = cfg.family in ("egnn", "meshgraphnet")
        arrays = _random_graph_arrays(rng, n=64, e=256, d_feat=cfg.d_feat,
                                      n_out=cfg.n_out, with_pos=with_pos,
                                      task=cfg.task)

        def loss_fn(params, batch):
            g = GraphBatch(nodes=batch["nodes"], senders=batch["senders"],
                           receivers=batch["receivers"],
                           pos=batch.get("pos"))
            return mod.loss_fn(params, cfg, g, batch["labels"])
        return cfg, loss_fn, params, arrays

    if spec.family == "recsys":
        from repro.models.recsys import autoint as A
        cfg = spec.smoke_cfg()
        params = A.init_params(cfg, key)
        B = 16
        batch = {
            "ids": jnp.asarray(rng.integers(0, cfg.total_rows,
                                            (B, cfg.n_sparse)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, 2, B), jnp.float32),
        }
        return cfg, trainer.recsys_loss(cfg), params, batch

    raise ValueError(spec.family)


def run_smoke_step(spec: ArchSpec, *, seed: int = 0) -> Dict[str, Any]:
    """One jitted train step on the reduced config; returns diagnostics."""
    from repro.optim import adam
    cfg, loss_fn, params, batch = smoke_setup(spec, seed=seed)
    acfg = adam.AdamConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(trainer.build_train_step(loss_fn, acfg))
    opt = adam.init_state(params, acfg)
    p1, o1, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    finite = all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p1))
    return {"cfg": cfg, "loss": loss, "params": p1, "opt": o1,
            "metrics": metrics, "finite": finite,
            "shapes_ok": jax.tree.all(jax.tree.map(
                lambda a, b: a.shape == b.shape, params, p1))}
