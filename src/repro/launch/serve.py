"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Stands up the batched ServeEngine on a reduced config, drives a synthetic
request workload through continuous batching, and reports latency/throughput
percentiles — the CPU-scale rehearsal of the decode_32k / long_500k cells
(whose full-scale programs are proven by the dry-run).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_arch
from repro.models.transformer import model as M
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=160)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if spec.family != "lm":
        raise SystemExit("serving launcher targets LM archs")
    cfg = spec.smoke_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, slots=args.slots,
                      cache_len=args.cache_len)

    rng = np.random.default_rng(args.seed)
    t_submit = {}
    for uid in range(args.requests):
        S = int(rng.integers(8, 64))
        req = Request(uid=uid,
                      prompt=rng.integers(0, cfg.vocab, S),
                      max_new_tokens=args.max_new)
        t_submit[uid] = time.time()
        eng.submit(req)

    t0 = time.time()
    finished = eng.run_until_drained()
    wall = time.time() - t0
    n_tok = sum(len(r.out) for r in finished)
    print(f"served {len(finished)}/{args.requests} requests, "
          f"{n_tok} tokens in {wall:.2f}s "
          f"({n_tok / max(wall, 1e-9):.1f} tok/s aggregate)")
    assert len(finished) == args.requests, "engine dropped requests"
    for r in finished[:3]:
        print(f"  req {r.uid}: {len(r.out)} tokens, first 8: {r.out[:8]}")


if __name__ == "__main__":
    main()
