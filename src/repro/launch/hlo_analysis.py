"""Collective-traffic analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` carries no collective information, so the
roofline's collective term is derived here: we parse ``compiled.as_text()``,
attribute every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` to its enclosing computation, and
multiply by loop trip counts (XLA stamps ``known_trip_count`` on each
``while`` op, so ``lax.scan`` bodies are counted exactly).

Two byte totals per op type:
  * ``operand_bytes`` — Σ input sizes (the spec'd convention);
  * ``wire_bytes``    — per-device traffic under the standard ring models:
        all-gather        (g−1)/g · output
        all-reduce        2·(g−1)/g · input
        reduce-scatter    (g−1)/g · input
        all-to-all        (g−1)/g · input
        collective-permute  input
The HLO is the per-device program, so these are per-chip bytes; the roofline
divides by per-link bandwidth.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string, incl. tuple shapes."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue  # token[] etc.
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    operand_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    wire_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def as_dict(self) -> Dict:
        return {
            "operand_bytes": dict(self.operand_bytes),
            "wire_bytes": dict(self.wire_bytes),
            "counts": dict(self.counts),
            "total_operand_bytes": self.total_operand_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-_]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)"
    r".*?condition=(%[\w\.\-_]+)"
    r".*?body=(%[\w\.\-_]+)", re.DOTALL)
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"?(\d+)"?')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w\.\-_]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\((.*?)\)(?:,|$)")
_CALLS_RE = re.compile(r"calls=(%[\w\.\-_]+)")
_BRANCH_RE = re.compile(
    r"(?:true_computation|false_computation)=(%[\w\.\-_]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _split_computations(txt: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in txt.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
                continue
            if line.startswith("}"):
                cur = None
                continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Trip-count multiplier per computation (entry = 1), propagated through
    while bodies/conditions and call/fusion references."""
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for line in lines:
            if " while(" in line:
                m = _WHILE_RE.search(line)
                if not m:
                    continue
                cond, body = m.group(1), m.group(2)
                t = _TRIP_RE.search(line)
                trip = float(t.group(1)) if t else 1.0
                edges[name].append((body, trip))
                edges[name].append((cond, trip))
            for cm in _CALLS_RE.finditer(line):
                edges[name].append((cm.group(1), 1.0))
            for cm in _BRANCH_RE.finditer(line):
                edges[name].append((cm.group(1), 1.0))
            for cm in _BRANCHES_RE.finditer(line):
                for b in cm.group(1).split(","):
                    b = b.strip()
                    if b.startswith("%"):
                        edges[name].append((b, 1.0))

    entry = None
    for name, lines in comps.items():
        if name != "__entry__" and comps.get("__entry__") is lines:
            entry = name
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        return mult
    # fixed-point propagation (call graph is a DAG; loop once per depth)
    mult[entry] = 1.0
    frontier = [entry]
    seen_depth = 0
    while frontier and seen_depth < 64:
        nxt = []
        for src in frontier:
            for dst, trip in edges.get(src, ()):
                new = mult[src] * trip
                if new > mult[dst]:
                    mult[dst] = new
                    nxt.append(dst)
        frontier = nxt
        seen_depth += 1
    return mult


def analyze_collectives(hlo_text: str, *, default_group: int = 1
                        ) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps)
    stats = CollectiveStats()
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        k = mult.get(name, 1.0) or 1.0
        for line in lines:
            m = _OPLINE_RE.match(line)
            if not m:
                continue
            out_shape, op, operands = m.group(1), m.group(2), m.group(3)
            op = op.replace("-start", "")
            g = _group_size(line, default_group)
            out_b = shape_bytes(out_shape)
            in_b = 0
            # operand list: %name references only; shapes unavailable — use
            # output-based inference per op type (exact for these ops).
            if op == "all-gather":
                in_b = out_b // max(g, 1)
                wire = out_b * (g - 1) / max(g, 1)
            elif op == "all-reduce":
                in_b = out_b
                wire = 2.0 * in_b * (g - 1) / max(g, 1)
            elif op == "reduce-scatter":
                in_b = out_b * g
                wire = in_b * (g - 1) / max(g, 1)
            elif op == "all-to-all":
                in_b = out_b
                wire = in_b * (g - 1) / max(g, 1)
            else:  # collective-permute
                in_b = out_b
                wire = float(in_b)
            stats.operand_bytes[op] += k * in_b
            stats.wire_bytes[op] += k * wire
            stats.counts[op] += k
    return stats


def loop_report(hlo_text: str) -> List[Tuple[str, float]]:
    """(body name, trip count) for every while in the module — debugging."""
    out = []
    for m in _WHILE_RE.finditer(hlo_text):
        t = _TRIP_RE.search(hlo_text[m.start():m.start() + 2000])
        out.append((m.group(2), float(t.group(1)) if t else -1.0))
    return out
