"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a REDUCED-config training job on the host devices (this container is
CPU-only; the same code path jits against the production mesh when real
chips are present — the dry-run proves those programs compile).  Includes
the full fault-tolerance loop: atomic checkpointing, auto-resume, and a
``--simulate-preemption`` flag that kills the loop mid-run so the restart
path is exercised end-to-end.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_arch
from repro.ckpt.checkpoint import Checkpointer
from repro.data import pipeline as dp
from repro.launch import smoke
from repro.optim import adam
from repro.train import trainer


def data_for(spec, cfg, batch: int, seq: int, seed: int):
    if spec.family == "lm":
        return dp.lm_stream(cfg.vocab, batch, seq, seed=seed)
    if spec.family == "recsys":
        return dp.recsys_stream(cfg.n_sparse, cfg.rows_per_field, batch,
                                seed=seed)
    if spec.family == "gnn":
        def gen():
            step = 0
            while True:
                _, _, _, batch_arrays = None, None, None, None
                _, loss_fn, _, arrays = smoke.smoke_setup(spec,
                                                          seed=seed + step)
                yield arrays
                step += 1
        return gen()
    raise ValueError(spec.family)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-dtype", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--simulate-preemption", type=int, default=0,
                    help="raise SystemExit after N steps (restart drill)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg, loss_fn, params, _ = smoke.smoke_setup(spec, seed=args.seed)
    acfg = adam.AdamConfig(lr=args.lr, warmup_steps=20,
                           total_steps=args.steps)
    tcfg = trainer.TrainConfig(microbatches=args.microbatches,
                               grad_dtype=args.grad_dtype)
    step_fn = jax.jit(trainer.build_train_step(loss_fn, acfg, tcfg),
                      donate_argnums=(0, 1))
    opt = adam.init_state(params, acfg)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt is not None and ckpt.latest_step is not None:
        params, opt, start = ckpt.restore(ckpt.latest_step, params, opt)
        print(f"resumed from step {start}")

    if spec.family == "lm":
        stream = dp.lm_stream(cfg.vocab, args.batch, args.seq,
                              seed=args.seed, start=start)
    elif spec.family == "recsys":
        stream = dp.recsys_stream(cfg.n_sparse, cfg.rows_per_field,
                                  args.batch, seed=args.seed, start=start)
    else:
        stream = data_for(spec, cfg, args.batch, args.seq, args.seed)

    t0 = time.time()
    for i, batch in enumerate(stream):
        step = start + i
        if step >= args.steps:
            break
        params, opt, metrics = step_fn(params, opt, batch)
        if args.log_every and step % args.log_every == 0:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save(params, opt, step + 1)
            print(f"checkpoint -> {path}")
        if args.simulate_preemption and i + 1 >= args.simulate_preemption:
            print("simulated preemption — relaunch to resume")
            raise SystemExit(75)
    print(f"done: {args.steps} steps, final loss "
          f"{float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
