import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including repro.*):
# jax locks the device count at first initialization, and the production
# meshes below need 512 placeholder host devices.  Do NOT set this globally —
# smoke tests and benchmarks must see the real single device.

"""Multi-pod dry-run (deliverable e).

For every assigned (architecture × input shape) cell, lower + compile the
production step on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh,
print ``compiled.memory_analysis()`` (proves it fits) and
``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline), parse the
collective schedule out of the partitioned HLO, and append everything to a
results JSON consumed by ``benchmarks/roofline.py``.

Usage:
  python -m repro.launch.dryrun --list
  python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
  python -m repro.launch.dryrun --all --orchestrate   # subprocess per cell

``--orchestrate`` isolates each cell in a fresh process (a pathological
compile cannot take down the sweep; memory is returned after each cell) and
skips cells already present in the JSON, so the sweep is resumable.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

RESULTS_DEFAULT = "results/dryrun.json"


def _load(path: str) -> Dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _store(path: str, results: Dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def _mesh(tag: str):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(tag == "multi"))


def _lower_compile(low, label: str, verbose: bool) -> Dict:
    import jax  # noqa: F401
    from repro.launch import hlo_analysis as H
    t0 = time.time()
    lowered = low.lower()
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    out: Dict = {"lower_s": round(t1 - t0, 2),
                 "compile_s": round(t2 - t1, 2)}
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            "peak_bytes": int(ma.peak_memory_in_bytes),
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
        }
        if verbose:
            print(f"[{label}] memory_analysis: {ma}")
    except Exception as e:  # pragma: no cover - backend-specific
        out["memory"] = {"error": str(e)}
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out["cost"] = {"flops": float(ca.get("flops", -1.0)),
                   "bytes_accessed": float(ca.get("bytes accessed", -1.0))}
    if verbose:
        print(f"[{label}] cost_analysis: flops={out['cost']['flops']:.4g} "
              f"bytes={out['cost']['bytes_accessed']:.4g}")
    txt = compiled.as_text()
    out["collectives"] = H.analyze_collectives(txt).as_dict()
    out["hlo_chars"] = len(txt)
    return out


def _parse_overrides(items) -> Dict:
    out: Dict = {}
    for item in items or ():
        k, _, v = item.partition("=")
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def run_cell(arch: str, shape: str, mesh_tag: str, *, probes: bool,
             out_path: str, verbose: bool = True,
             overrides: Optional[Dict] = None, tag: str = "") -> Dict:
    from repro.configs import get_arch
    from repro.launch.cells import make_cell

    overrides = overrides or {}
    key = f"{mesh_tag}:{arch}/{shape}" + (f"@{tag}" if tag else "")
    spec = get_arch(arch)
    sh = spec.shape(shape)
    rec: Dict = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                 "kind": sh.kind, "status": "ok", "note": sh.note,
                 "overrides": overrides, "variant": tag}
    if sh.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = sh.skip
        print(f"[{key}] SKIPPED (by rule): {sh.skip}")
        return _merge(out_path, key, rec)

    mesh = _mesh(mesh_tag)
    try:
        cell = make_cell(arch, shape, mesh, **overrides)
        rec.update(model_flops=cell.model_flops,
                   microbatches=cell.microbatches,
                   n_scan_layers=cell.n_scan_layers,
                   opt_flops=cell.opt_flops, opt_bytes=cell.opt_bytes,
                   param_count=cell.param_count,
                   layer_param_count=cell.layer_param_count,
                   family=cell.family)
        rec.update(_lower_compile(cell.main, key, verbose))
        if probes and cell.probes:
            rec["probes"] = {}
            for pname, plow in cell.probes.items():
                rec["probes"][pname] = _lower_compile(
                    plow, f"{key}#{pname}", verbose)
        print(f"[{key}] OK compile={rec['compile_s']}s "
              f"peak={rec['memory'].get('peak_bytes', -1)/1e9:.2f}GB "
              f"coll_wire={rec['collectives']['total_wire_bytes']/1e9:.3f}GB")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{key}] ERROR {rec['error']}")
    return _merge(out_path, key, rec)


def _merge(out_path: str, key: str, rec: Dict) -> Dict:
    results = _load(out_path)
    results[key] = rec
    _store(out_path, results)
    return rec


def iter_all_cells(include_pagerank: bool = True):
    from repro.configs import get_arch, iter_cells, list_archs
    for spec, shape in iter_cells(include_skipped=True):
        yield spec.arch_id, shape.name
    if include_pagerank:
        pr = get_arch("pagerank-df")
        for shape in pr.shapes:
            yield pr.arch_id, shape.name


def orchestrate(mesh_tags, out_path: str, *, probes: bool,
                timeout_s: int = 2400) -> int:
    done = _load(out_path)
    failures = 0
    for mesh_tag in mesh_tags:
        for arch, shape in iter_all_cells():
            key = f"{mesh_tag}:{arch}/{shape}"
            if key in done and done[key].get("status") in ("ok", "skipped"):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_tag,
                   "--out", out_path]
            if probes and mesh_tag == "single":
                cmd.append("--probes")
            src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
            env = dict(os.environ)
            env["PYTHONPATH"] = src_dir + os.pathsep + env.get(
                "PYTHONPATH", "")
            print(f"=== {key} ===", flush=True)
            try:
                r = subprocess.run(cmd, timeout=timeout_s, env=env)
                if r.returncode != 0:
                    failures += 1
                    _merge(out_path, key, {
                        "arch": arch, "shape": shape, "mesh": mesh_tag,
                        "status": "error",
                        "error": f"subprocess rc={r.returncode}"})
            except subprocess.TimeoutExpired:
                failures += 1
                _merge(out_path, key, {
                    "arch": arch, "shape": shape, "mesh": mesh_tag,
                    "status": "error", "error": f"timeout {timeout_s}s"})
        done = _load(out_path)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--probes", action="store_true",
                    help="also compile the L=1/L=2 probe programs "
                         "(exact scan-flop correction; single-pod only)")
    ap.add_argument("--orchestrate", action="store_true",
                    help="subprocess-per-cell sweep, resumable")
    ap.add_argument("--out", default=RESULTS_DEFAULT)
    ap.add_argument("--override", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="§Perf variant knob (e.g. exchange=delta, "
                         "pad_vocab_to_multiple=2048, rules:seq=model)")
    ap.add_argument("--tag", default="",
                    help="variant tag; result stored as <cell>@<tag>")
    args = ap.parse_args()

    if args.list:
        for arch, shape in iter_all_cells():
            print(f"{arch:24s} {shape}")
        return

    tags = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.orchestrate or (args.all and not args.arch):
        rc = orchestrate(tags, args.out, probes=True)
        summary = _load(args.out)
        n_ok = sum(1 for v in summary.values() if v.get("status") == "ok")
        n_skip = sum(1 for v in summary.values()
                     if v.get("status") == "skipped")
        n_err = len(summary) - n_ok - n_skip
        print(f"dry-run sweep: {n_ok} ok / {n_skip} skipped-by-rule / "
              f"{n_err} errors")
        sys.exit(1 if rc else 0)

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    for tag in tags:
        run_cell(args.arch, args.shape, tag, probes=args.probes,
                 out_path=args.out,
                 overrides=_parse_overrides(args.override), tag=args.tag)


if __name__ == "__main__":
    main()
