"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``xla_force_host_platform_device_count=512`` before any jax import and only
then calls it.

Axis semantics (DESIGN.md §5):
  * "pod"   — crosses the inter-pod DCN/ICI boundary (2 pods × 256 chips);
    used for data parallelism and (MoE) expert parallelism.
  * "data"  — intra-pod data parallel / FSDP / ZeRO axis.
  * "model" — tensor/sequence-parallel axis (heads, ff, vocab, cache_seq).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax


def _mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]
          ) -> jax.sharding.Mesh:
    # jax.make_mesh(axis_types=...) is version-gated; build from the raw
    # device array instead (works across the jax versions we support)
    devs = np.asarray(jax.devices()[:int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(shape: Optional[Tuple[int, ...]] = None,
                   axes: Tuple[str, ...] = ("data",)) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    if shape is None:
        shape = (n,)
    if int(np.prod(shape)) != n:
        raise ValueError(f"mesh shape {shape} != device count {n}")
    return _mesh(tuple(shape), axes)


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
