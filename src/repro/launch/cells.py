"""Cell builders — map (architecture × input shape × mesh) to a lowerable,
sharded step function.  This is the single source of truth consumed by the
multi-pod dry-run, the roofline analyzer, and the per-arch smoke tests.

Design notes
------------
* ``main`` lowers the PRODUCTION program (scan-over-layers, scan-over-
  microbatches) — its ``memory_analysis`` and HLO collective schedule are
  exact.  XLA's ``cost_analysis`` counts a ``while`` body once, so LM cells
  also carry two cheap *probes* (the same step at n_layers=1 and 2,
  single microbatch): the roofline reconstructs exact per-step FLOPs/bytes
  as   opt + microbatches · (P1 + (L−1)·(P2−P1) − opt).
  GNN / recsys / pagerank mains unroll their (short) layer loops, so their
  cost analysis is already exact and they carry no probes.
* Inputs are ``ShapeDtypeStruct``s — nothing is allocated (the full configs
  reach 340B params / billion-edge graphs).
* All sharding comes from the logical-axis rule tables
  (:mod:`repro.dist.sharding`) + per-arch overrides in the registry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec, ShapeSpec, get_arch
from repro.dist import sharding as S
from repro.dist.api import use_rules
from repro.launch import flops as F
from repro.optim import adam
from repro.train import trainer

SDS = jax.ShapeDtypeStruct


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass
class Lowerable:
    fn: Callable
    args: Tuple
    in_shardings: Any

    def lower(self):
        jf = jax.jit(self.fn, in_shardings=self.in_shardings)
        return jf.lower(*self.args)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    family: str
    mesh: Mesh
    main: Lowerable
    probes: Dict[str, Lowerable] = dataclasses.field(default_factory=dict)
    # roofline bookkeeping
    model_flops: float = 0.0           # analytic useful flops, full step
    microbatches: int = 1
    n_scan_layers: int = 1             # L for the probe correction
    opt_flops: float = 0.0             # analytic optimizer cost (train)
    opt_bytes: float = 0.0
    param_count: int = 0
    layer_param_count: int = 0         # params of ONE layer (probe algebra)
    note: str = ""

    @property
    def name(self) -> str:
        return f"{self.arch_id}/{self.shape_name}"


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _rules(spec: ArchSpec, mesh: Mesh) -> S.Rules:
    base = {"lm": S.LM_RULES, "gnn": S.GNN_RULES,
            "recsys": S.RECSYS_RULES}.get(spec.family, S.LM_RULES)
    rules = dict(base)
    rules.update(spec.rules_override)
    return rules


def _shard(mesh, rules, logical, shape) -> NamedSharding:
    return NamedSharding(mesh, S.logical_to_spec(logical, rules, mesh, shape))


def _tree_shard(mesh, rules, logical_tree, abstract_tree):
    return jax.tree.map(
        lambda lg, a: _shard(mesh, rules, lg, a.shape),
        logical_tree, abstract_tree, is_leaf=lambda x: isinstance(x, tuple))


def _opt_analytics(n_params: int, *, param_bytes: int, state_bytes: int,
                   accum_bytes: int) -> Tuple[float, float]:
    """Analytic AdamW cost: ~14 flops/param (incl. global-norm clip);
    bytes = p(r+w) + g(r) + m,v(r+w)."""
    fl = 14.0 * n_params
    by = n_params * (2 * param_bytes + accum_bytes + 4 * state_bytes)
    return fl, by


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_abstract_params(cfg):
    from repro.models.transformer import model as M
    return M.abstract_params(cfg)


def _lm_param_shardings(cfg, mesh, rules):
    from repro.models.transformer import model as M
    ap = M.abstract_params(cfg)
    lg = M.param_logical(cfg)
    return ap, _tree_shard(mesh, rules, lg, ap)


def _lm_opt_abstract(cfg, ap, mesh, rules, state_dtype):
    from repro.models.transformer import model as M
    lg = M.param_logical(cfg)
    sd = jnp.dtype(state_dtype)
    am = {k: SDS(v.shape, sd) for k, v in ap.items()}
    sh = {}
    for k, v in am.items():
        z = S.zero1_logical(lg[k], v.shape, mesh, rules)
        sh[k] = _shard(mesh, rules, z, v.shape)
    aopt = {"m": am, "v": am, "step": SDS((), jnp.int32)}
    oshard = {"m": sh, "v": sh, "step": NamedSharding(mesh, P())}
    return aopt, oshard


def _lm_train_lowerable(spec, shape, mesh, *, n_layers, microbatches,
                        global_batch, scan_layers, exec_kw):
    cfg = spec.build_cfg(n_layers=n_layers, scan_layers=scan_layers)
    rules = _rules(spec, mesh)
    state_dtype = exec_kw.get("state_dtype", "float32")
    tcfg = trainer.TrainConfig(
        microbatches=microbatches,
        accum_dtype=exec_kw.get("accum_dtype", "float32"))
    acfg = adam.AdamConfig(state_dtype=jnp.dtype(state_dtype))
    step = trainer.build_train_step(trainer.lm_loss(cfg), acfg, tcfg)

    def fn(params, opt_state, batch):
        with use_rules(rules, mesh):
            return step(params, opt_state, batch)

    ap, psh = _lm_param_shardings(cfg, mesh, rules)
    aopt, osh = _lm_opt_abstract(cfg, ap, mesh, rules, state_dtype)
    seq = shape.dim("seq_len")
    batch = {"tokens": SDS((global_batch, seq), jnp.int32),
             "labels": SDS((global_batch, seq), jnp.int32)}
    bsh = {k: _shard(mesh, rules, ("batch", "seq"), v.shape)
           for k, v in batch.items()}
    return Lowerable(fn, (ap, aopt, batch), (psh, osh, bsh))


def _lm_serve_lowerable(spec, shape, mesh, *, n_layers, scan_layers):
    from repro.models.transformer import model as M
    cfg = spec.build_cfg(n_layers=n_layers, scan_layers=scan_layers)
    rules = _rules(spec, mesh)
    B = shape.dim("global_batch")
    seq = shape.dim("seq_len")
    ap, psh = _lm_param_shardings(cfg, mesh, rules)

    if shape.kind == "prefill":
        def fn(params, tokens):
            with use_rules(rules, mesh):
                return M.prefill(params, tokens, cfg, cache_len=seq)

        tokens = SDS((B, seq), jnp.int32)
        tsh = _shard(mesh, rules, ("batch", "seq"), tokens.shape)
        return Lowerable(fn, (ap, tokens), (psh, tsh))

    # decode: one new token against a seq-long KV cache
    cshape = M.cache_shapes(cfg, B, seq)
    clog = M.cache_logical()
    cdt = jnp.dtype(cfg.cache_dtype or cfg.dtype)
    cache = {k: SDS(v, cdt) for k, v in cshape.items()}
    csh = {k: _shard(mesh, rules, clog[k], cshape[k]) for k in cache}

    def fn(params, cache, token, position):
        with use_rules(rules, mesh):
            return M.decode_step(params, cache, token, position, cfg)

    token = SDS((B,), jnp.int32)
    pos = SDS((), jnp.int32)
    tsh = _shard(mesh, rules, ("batch",), token.shape)
    return Lowerable(fn, (ap, cache, token, pos),
                     (psh, csh, tsh, NamedSharding(mesh, P())))


def _lm_pipeline_cell(spec, shape, mesh, *, microbatches: int = 32,
                      state_dtype: str = "bfloat16") -> Cell:
    """§Perf variant: GPipe pipeline over 'model' + Megatron TP over 'data'
    (weights stationary — activation-sized collectives).  Train shapes only.
    """
    from repro.models.transformer import model as M
    from repro.train.pipeline import (PipelineConfig, build_pipeline_loss,
                                      pipeline_param_shardings)
    cfg = spec.build_cfg()
    B = shape.dim("global_batch")
    seq = shape.dim("seq_len")
    pcfg = PipelineConfig(stage_axis="model", tp_axis="data",
                          dp_axis="pod" if "pod" in mesh.axis_names
                          else None,
                          microbatches=microbatches)
    loss = build_pipeline_loss(cfg, pcfg, mesh, global_batch=B, seq=seq)
    acfg = adam.AdamConfig(state_dtype=jnp.dtype(state_dtype))
    step = trainer.build_train_step(loss, acfg)

    ap = M.abstract_params(cfg)
    psh = pipeline_param_shardings(cfg, pcfg, mesh)
    sd = jnp.dtype(state_dtype)
    am = {k: SDS(v.shape, sd) for k, v in ap.items()}
    aopt = {"m": am, "v": am, "step": SDS((), jnp.int32)}
    osh = {"m": psh, "v": psh, "step": NamedSharding(mesh, P())}
    batch = {"tokens": SDS((B, seq), jnp.int32),
             "labels": SDS((B, seq), jnp.int32)}
    bspec = P("pod") if "pod" in mesh.axis_names else P()
    bsh = {k: NamedSharding(mesh, bspec) for k in batch}
    low = Lowerable(step, (ap, aopt, batch), (psh, osh, bsh))
    n_stages = mesh.shape["model"]
    bubble = (microbatches + n_stages - 1) / microbatches
    return Cell(
        arch_id=spec.arch_id, shape_name=shape.name, kind=shape.kind,
        family="lm", mesh=mesh, main=low,
        model_flops=F.lm_model_flops(cfg, shape),
        microbatches=microbatches, n_scan_layers=cfg.n_layers,
        param_count=cfg.param_count(),
        note=f"pipeline: {n_stages} stages × TP{mesh.shape['data']}, "
             f"{microbatches} microbatches, bubble ×{bubble:.2f}")


def _lm_cell(spec, shape, mesh) -> Cell:
    exec_kw = spec.exec_for(shape.name)
    mb = exec_kw.get("microbatches", 1)
    cfg_full = spec.build_cfg()
    L = cfg_full.n_layers
    if shape.kind == "train":
        B = shape.dim("global_batch")
        main = _lm_train_lowerable(
            spec, shape, mesh, n_layers=L, microbatches=mb, global_batch=B,
            scan_layers=True, exec_kw=exec_kw)
        probes = {
            "layer1": _lm_train_lowerable(
                spec, shape, mesh, n_layers=1, microbatches=1,
                global_batch=B // mb, scan_layers=False, exec_kw=exec_kw),
            "layer2": _lm_train_lowerable(
                spec, shape, mesh, n_layers=2, microbatches=1,
                global_batch=B // mb, scan_layers=False, exec_kw=exec_kw),
        }
        pb = jnp.dtype(cfg_full.param_dtype).itemsize
        sb = jnp.dtype(exec_kw.get("state_dtype", "float32")).itemsize
        ab = jnp.dtype(exec_kw.get("accum_dtype", "float32")).itemsize
        ofl, oby = _opt_analytics(cfg_full.param_count(), param_bytes=pb,
                                  state_bytes=sb, accum_bytes=ab)
    else:
        main = _lm_serve_lowerable(spec, shape, mesh, n_layers=L,
                                   scan_layers=True)
        probes = {
            "layer1": _lm_serve_lowerable(spec, shape, mesh, n_layers=1,
                                          scan_layers=False),
            "layer2": _lm_serve_lowerable(spec, shape, mesh, n_layers=2,
                                          scan_layers=False),
        }
        mb, ofl, oby = 1, 0.0, 0.0
    n_total = cfg_full.param_count()
    cfg_l1 = spec.build_cfg(n_layers=1)
    cfg_l2 = spec.build_cfg(n_layers=2)
    layer_params = cfg_l2.param_count() - cfg_l1.param_count()
    return Cell(
        arch_id=spec.arch_id, shape_name=shape.name, kind=shape.kind,
        family="lm", mesh=mesh, main=main, probes=probes,
        model_flops=F.lm_model_flops(cfg_full, shape),
        microbatches=mb, n_scan_layers=L, opt_flops=ofl, opt_bytes=oby,
        param_count=n_total, layer_param_count=layer_params,
        note=shape.note)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cfg_for_shape(spec: ArchSpec, shape: ShapeSpec, **kw):
    if shape.kind == "batched_small":
        task = "graph_reg"
        d_feat, n_out = shape.dim("d_feat"), shape.dim("n_out")
    else:
        task = "node_clf"
        d_feat, n_out = shape.dim("d_feat"), shape.dim("n_out")
    return spec.build_cfg(d_feat=d_feat, n_out=n_out, task=task,
                          scan_layers=False, **kw)


def _gnn_batch_loss(cfg, *, n_graphs: int = 1):
    from repro.models.gnn import get_family
    from repro.models.gnn.common import GraphBatch
    mod = get_family(cfg)

    def fn(params, batch):
        g = GraphBatch(
            nodes=batch["nodes"], senders=batch["senders"],
            receivers=batch["receivers"], pos=batch.get("pos"),
            graph_id=batch.get("graph_id"), n_graphs=n_graphs,
            node_mask=batch.get("node_mask"))
        return mod.loss_fn(params, cfg, g, batch["labels"])
    return fn


_GNN_BATCH_LOGICAL = {
    "nodes": ("nodes", None), "senders": ("edges",),
    "receivers": ("edges",), "pos": ("nodes", None),
    "graph_id": ("nodes",), "node_mask": ("nodes",),
    "labels": ("nodes",), "labels_graph": ("batch", None),
}


def _gnn_train_lowerable(spec, shape, mesh, cfg, batch, *, n_graphs=1,
                         loss=None):
    from repro.models.gnn import get_family
    rules = _rules(spec, mesh)
    loss_fn = loss or _gnn_batch_loss(cfg, n_graphs=n_graphs)
    acfg = adam.AdamConfig()
    step = trainer.build_train_step(loss_fn, acfg)

    def fn(params, opt_state, batch):
        with use_rules(rules, mesh):
            return step(params, opt_state, batch)

    mod = get_family(cfg)
    shapes = mod.shapes(cfg)
    dt = jnp.dtype(cfg.dtype)
    ap = {k: SDS(v, dt) for k, v in shapes.items()}
    psh = {k: NamedSharding(mesh, P()) for k in ap}   # GNN params are small
    am = {k: SDS(v, jnp.float32) for k, v in shapes.items()}
    aopt = {"m": am, "v": am, "step": SDS((), jnp.int32)}
    osh = {"m": psh, "v": psh, "step": NamedSharding(mesh, P())}

    bsh = {}
    for k, v in batch.items():
        if k == "labels":
            lg = (_GNN_BATCH_LOGICAL["labels_graph"]
                  if cfg.task == "graph_reg" else
                  _GNN_BATCH_LOGICAL["labels"])
        elif k.startswith("hop"):
            lg = ("batch",) + (None,) * (len(v.shape) - 1)
        else:
            lg = _GNN_BATCH_LOGICAL[k]
        bsh[k] = _shard(mesh, rules, lg, v.shape)
    return Lowerable(fn, (ap, aopt, batch), (psh, osh, bsh))


def _gnn_cell(spec, shape, mesh) -> Cell:
    f32, i32 = jnp.float32, jnp.int32
    needs_pos = spec.build_cfg().family in ("egnn", "meshgraphnet")
    n_graphs = 1

    if shape.kind == "full_batch":
        cfg = _gnn_cfg_for_shape(spec, shape)
        N = _round_up(shape.dim("n_nodes"), 4096)
        E = _round_up(shape.dim("n_edges"), 4096)
        batch = {"nodes": SDS((N, cfg.d_feat), f32),
                 "senders": SDS((E,), i32), "receivers": SDS((E,), i32),
                 "labels": SDS((N,), i32)}
        if needs_pos:
            batch["pos"] = SDS((N, 3), f32)
        low = _gnn_train_lowerable(spec, shape, mesh, cfg, batch)
    elif shape.kind == "sampled" and spec.build_cfg().family == "graphsage":
        cfg = _gnn_cfg_for_shape(spec, shape,
                                 sample_sizes=(shape.dim("fanout1"),
                                               shape.dim("fanout2")))
        B, f1, f2 = (shape.dim("batch_nodes"), shape.dim("fanout1"),
                     shape.dim("fanout2"))
        Fe = cfg.d_feat
        batch = {"hop0": SDS((B, Fe), f32), "hop1": SDS((B, f1, Fe), f32),
                 "hop2": SDS((B, f1, f2, Fe), f32),
                 "labels": SDS((B,), i32)}
        low = _gnn_train_lowerable(
            spec, shape, mesh, cfg, batch,
            loss=trainer.gnn_sampled_loss(cfg))
    elif shape.kind == "sampled":
        # sampled-subgraph form for archs without a dense-hop path: the host
        # sampler materializes the fanout block as one padded GraphBatch
        cfg = _gnn_cfg_for_shape(spec, shape)
        B, f1, f2 = (shape.dim("batch_nodes"), shape.dim("fanout1"),
                     shape.dim("fanout2"))
        N = _round_up(B * (1 + f1 + f1 * f2), 4096)
        E = _round_up(B * f1 + B * f1 * f2, 4096)
        batch = {"nodes": SDS((N, cfg.d_feat), f32),
                 "senders": SDS((E,), i32), "receivers": SDS((E,), i32),
                 "labels": SDS((N,), i32), "node_mask": SDS((N,), jnp.bool_)}
        if needs_pos:
            batch["pos"] = SDS((N, 3), f32)
        low = _gnn_train_lowerable(spec, shape, mesh, cfg, batch)
    elif shape.kind == "batched_small":
        n_graphs = shape.dim("batch")
        cfg = _gnn_cfg_for_shape(spec, shape)
        N = n_graphs * shape.dim("n_nodes")
        E = n_graphs * shape.dim("n_edges")
        batch = {"nodes": SDS((N, cfg.d_feat), f32),
                 "senders": SDS((E,), i32), "receivers": SDS((E,), i32),
                 "graph_id": SDS((N,), i32),
                 "labels": SDS((n_graphs, cfg.n_out), f32)}
        if needs_pos:
            batch["pos"] = SDS((N, 3), f32)
        low = _gnn_train_lowerable(spec, shape, mesh, cfg, batch,
                                   n_graphs=n_graphs)
    else:
        raise ValueError(shape.kind)

    return Cell(
        arch_id=spec.arch_id, shape_name=shape.name, kind=shape.kind,
        family="gnn", mesh=mesh, main=low,
        model_flops=F.gnn_model_flops(cfg, shape),
        note=shape.note)


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _recsys_cell(spec, shape, mesh) -> Cell:
    from repro.models.recsys import autoint as A
    cfg = spec.build_cfg()
    rules = _rules(spec, mesh)
    ap = A.abstract_params(cfg)
    lg = A.param_logical(cfg)
    psh = _tree_shard(mesh, rules, lg, ap)
    i64 = jnp.int32

    if shape.kind == "train":
        B = shape.dim("batch")
        acfg = adam.AdamConfig()
        step = trainer.build_train_step(trainer.recsys_loss(cfg), acfg)

        def fn(params, opt_state, batch):
            with use_rules(rules, mesh):
                return step(params, opt_state, batch)

        am = {k: SDS(v.shape, jnp.float32) for k, v in ap.items()}
        osh = {}
        for k, v in am.items():
            z = S.zero1_logical(lg[k], v.shape, mesh, rules)
            osh[k] = _shard(mesh, rules, z, v.shape)
        aopt = {"m": am, "v": am, "step": SDS((), jnp.int32)}
        oshard = {"m": osh, "v": osh, "step": NamedSharding(mesh, P())}
        batch = {"ids": SDS((B, cfg.n_sparse), i64),
                 "labels": SDS((B,), jnp.float32)}
        bsh = {"ids": _shard(mesh, rules, ("batch", None), batch["ids"].shape),
               "labels": _shard(mesh, rules, ("batch",),
                                batch["labels"].shape)}
        low = Lowerable(fn, (ap, aopt, batch), (psh, oshard, bsh))
    elif shape.kind == "serve":
        B = shape.dim("batch")

        def fn(params, ids):
            with use_rules(rules, mesh):
                return A.forward(params, cfg, ids)

        ids = SDS((B, cfg.n_sparse), i64)
        low = Lowerable(fn, (ap, ids),
                        (psh, _shard(mesh, rules, ("batch", None),
                                     ids.shape)))
    elif shape.kind == "retrieval":
        N = shape.dim("n_candidates")
        n_item = cfg.n_sparse - cfg.n_user_fields

        def fn(params, user_ids, cand_ids):
            with use_rules(rules, mesh):
                return A.retrieval_scores(params, cfg, user_ids, cand_ids)

        uids = SDS((1, cfg.n_user_fields), i64)
        cids = SDS((N, n_item), i64)
        low = Lowerable(
            fn, (ap, uids, cids),
            (psh, NamedSharding(mesh, P()),
             _shard(mesh, rules, ("candidates", None), cids.shape)))
    else:
        raise ValueError(shape.kind)

    return Cell(arch_id=spec.arch_id, shape_name=shape.name, kind=shape.kind,
                family="recsys", mesh=mesh, main=low,
                model_flops=F.recsys_model_flops(cfg, shape),
                note=shape.note)


# ---------------------------------------------------------------------------
# pagerank (the paper's workload): one distributed DF sweep
# ---------------------------------------------------------------------------

def _pagerank_cell(spec, shape, mesh, **overrides) -> Cell:
    from repro.core import distributed as D
    cfgd = spec.build_cfg(**overrides)
    n = shape.dim("n_vertices")
    deg = shape.dim("avg_degree")
    n_dev = int(np.prod(list(mesh.shape.values())))
    axes = tuple(mesh.axis_names)
    m = n * (deg + 1)                      # + self-loops
    m_loc = _round_up(int(m / n_dev * 1.05), 8)
    f32, i32 = jnp.float32, jnp.int32
    dg = D.DistGraph(
        n=n, n_pad=n, n_dev=n_dev,
        src_in=SDS((n_dev, m_loc), i32), dst_in=SDS((n_dev, m_loc), i32),
        src_out=SDS((n_dev, m_loc), i32), dst_out=SDS((n_dev, m_loc), i32),
        inv_deg=SDS((n,), f32), vertex_valid=SDS((n,), jnp.bool_))
    tau = 1e-7                             # f32 tolerance (DESIGN.md §2)
    sweep = D.make_sweep(
        dg, mesh, axes, alpha=cfgd["alpha"], tau=tau,
        tau_f=tau * cfgd["tau_f_ratio"], expand=True,
        exchange=cfgd["exchange"],
        delta_capacity=int(cfgd.get("delta_capacity", 1024)),
        local_gs_sweeps=int(cfgd.get("local_gs_sweeps", 1)),
        marks_dtype=jnp.dtype(cfgd.get("marks_dtype", "int32")))
    cache_w = n if cfgd["exchange"] == "delta" else 1
    args = (SDS((n,), f32), SDS((n,), jnp.bool_), SDS((n,), jnp.bool_),
            SDS((n_dev, cache_w), f32), dg.src_in, dg.dst_in, dg.src_out,
            dg.dst_out, dg.inv_deg, dg.vertex_valid)
    vec = NamedSharding(mesh, P(axes))
    slab = NamedSharding(mesh, P(axes, None))
    shard = (vec, vec, vec, slab, slab, slab, slab, slab, vec, vec)
    if cfgd["exchange"] == "ring":
        ring_cap = _round_up(int(m / (n_dev * n_dev) * 1.3) + 8, 8)
        ring_sds = SDS((n_dev, n_dev, ring_cap), i32)
        args = args + (ring_sds, ring_sds)
        shard = shard + (NamedSharding(mesh, P(axes, None, None)),) * 2
    low = Lowerable(sweep, args, shard)
    return Cell(arch_id=spec.arch_id, shape_name=shape.name, kind=shape.kind,
                family="pagerank", mesh=mesh, main=low,
                model_flops=F.pagerank_sweep_flops(n, m),
                note=f"exchange={cfgd['exchange']}; " + shape.note)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def make_cell(arch_id: str, shape_name: str, mesh: Mesh, **overrides) -> Cell:
    """Build one cell.  ``overrides`` are §Perf hillclimb knobs:
      * lm     — model-config fields (e.g. ``pad_vocab_to_multiple=2048``),
                 plus ``microbatches=N`` / ``rules:<axis>=<mesh axes>``;
      * pagerank — sweep config fields (e.g. ``exchange="delta"``).
    """
    spec = get_arch(arch_id)
    if overrides.pop("pipeline", False):
        pp_kw = {k.replace("pp_", ""): v for k, v in overrides.items()
                 if k.startswith("pp_")}
        return _lm_pipeline_cell(spec, spec.shape(shape_name), mesh,
                                 **pp_kw)
    if overrides and spec.family != "pagerank":
        rules_over = {}
        exec_over = dict(spec.exec_overrides)
        cfg_over = {}
        for k, v in overrides.items():
            if k.startswith("rules:"):
                rules_over[k.split(":", 1)[1]] = (None if v in ("none", "")
                                                  else v)
            elif k in ("microbatches", "state_dtype", "accum_dtype"):
                exec_over = {sn: {**spec.exec_overrides.get(sn, {}), k: v}
                             for sn in [shape_name]}
            else:
                cfg_over[k] = v
        base_build = spec.build_cfg

        def build2(**kw):
            merged = dict(cfg_over)
            merged.update(kw)          # caller-explicit keys win (probes)
            return base_build(**merged)

        spec = dataclasses.replace(
            spec, build_cfg=build2,
            rules_override={**spec.rules_override, **rules_over},
            exec_overrides=exec_over)
    shape = spec.shape(shape_name)
    if spec.family == "lm":
        return _lm_cell(spec, shape, mesh)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape, mesh)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape, mesh)
    if spec.family == "pagerank":
        return _pagerank_cell(spec, shape, mesh, **overrides)
    raise ValueError(spec.family)
